"""Backend-parity property suite: numpy vs jax, bit-exact.

The numpy backend is the bit-exactness reference (its kernels are the
seed code extracted verbatim into `repro.core.backend`); the jax backend
re-expresses the same three scheduler kernels on `jax.jit`/`lax` with
static shapes and pow2 padding.  This suite asserts the two backends are
indistinguishable at every level:

  * kernel level -- ladder-DRF container counts, the saturating probe and
    best-fit placement produce identical results on random instances,
    including fractional demands, zero-demand columns and score ties
    (placement is compared as the dense slave->count mapping: the (js,
    counts) PAIRING is the contract, the pair ORDER is not),
  * master level -- two DormMasters differing only in
    `OptimizerConfig.backend` stay bit-exact event-for-event through
    random arrival/completion/resize storms with ~60% fractional demands:
    same allocation matrices, same adjusted/started/pending sets, same
    delta/full solve counters.

Runs under hypothesis when available (CI installs it); falls back to a
seeded-random sweep of the same checks otherwise.  The whole module skips
cleanly when jax is not importable (bare images)."""
import numpy as np
import pytest

from repro.core import (ApplicationSpec, ClusterSpec, DormMaster,
                        OptimizerConfig, RecordingProtocol, ResourceVector,
                        backend_available, get_backend)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.skipif(not backend_available("jax"),
                                reason="jax not installed")

# Modest example counts: every distinct padded shape jit-compiles once
# per process, and the pow2 padding contract keeps that set small.
N_KERNEL = 60
N_MASTER = 8


def _backends():
    return get_backend("numpy"), get_backend("jax")


# ------------------------------------------------- kernel-level parity

def _rand_instance(rng):
    """(d, n_min, n_max, w, total): random ladder/probe instance with
    fractional demands, occasional zero columns and tight totals."""
    n = int(rng.integers(1, 13))
    m = int(rng.integers(2, 5))
    if rng.random() < 0.5:
        d = rng.integers(1, 9, size=(n, m)).astype(np.float64)
    else:
        d = np.round(rng.uniform(0.1, 8.0, size=(n, m)), 2)
    if rng.random() < 0.3:                      # zero-demand column
        d[:, int(rng.integers(m))] = 0.0
    n_min = rng.integers(1, 4, size=n).astype(np.int64)
    n_max = n_min + rng.integers(0, 9, size=n).astype(np.int64)
    w = rng.integers(1, 4, size=n).astype(np.float64)
    # Total capacity between "almost nothing fits" and "everything fits".
    scale = float(rng.uniform(0.3, 3.0))
    total = np.maximum(d.sum(axis=0) * scale, 1.0)
    if rng.random() < 0.2:
        total[int(rng.integers(m))] = 0.0       # a depleted resource
    return d, n_min, n_max, w, total


def _check_kernel_parity(seed: int) -> None:
    rng = np.random.default_rng(seed)
    np_be, jx_be = _backends()
    for _ in range(4):
        d, n_min, n_max, w, total = _rand_instance(rng)
        ref = np_be.ladder_counts(d, n_min, n_max, w, total)
        got = jx_be.ladder_counts(d, n_min, n_max, w, total)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref),
                                      err_msg=f"ladder seed={seed}")
        nm = n_max.astype(np.float64)
        assert (np_be.saturating_probe(d, nm, total)
                == jx_be.saturating_probe(d, nm, total)), f"probe {seed}"


def _check_place_parity(seed: int) -> None:
    """Dense-mapping equality for best-fit placement; forces score ties
    via duplicated slave rows."""
    rng = np.random.default_rng(seed)
    np_be, jx_be = _backends()
    for _ in range(4):
        b = int(rng.integers(2, 33))
        m = int(rng.integers(2, 5))
        cap = rng.integers(4, 17, size=(b, m)).astype(np.float64)
        if rng.random() < 0.5:                  # duplicate rows -> ties
            cap = cap[rng.integers(b, size=b)]
        used = cap * rng.uniform(0.0, 1.0, size=(b, m))
        free = cap - np.round(used, 1)
        inv_cap = np.where(cap > 0, 1.0 / np.maximum(cap, 1e-12), 0.0)
        if rng.random() < 0.5:
            di = rng.integers(1, 5, size=m).astype(np.float64)
        else:
            di = np.round(rng.uniform(0.2, 4.0, size=m), 2)
        need = int(rng.integers(1, 9))
        ref = np_be.place_counts(free, di, inv_cap, need)
        got = jx_be.place_counts(free, di, inv_cap, need)
        assert (ref is None) == (got is None), f"place feasibility {seed}"
        if ref is None:
            continue
        dense_r = np.zeros(b, dtype=np.int64)
        dense_g = np.zeros(b, dtype=np.int64)
        dense_r[ref[0]] = ref[1]
        dense_g[got[0]] = got[1]
        np.testing.assert_array_equal(dense_g, dense_r,
                                      err_msg=f"place seed={seed}")


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2 ** 32 - 1))
    @settings(max_examples=N_KERNEL, deadline=None)
    def test_kernel_counts_bit_exact(seed):
        _check_kernel_parity(seed)

    @given(st.integers(0, 2 ** 32 - 1))
    @settings(max_examples=N_KERNEL, deadline=None)
    def test_placement_mapping_identical(seed):
        _check_place_parity(seed)
else:                                                  # pragma: no cover
    @pytest.mark.parametrize("chunk", range(6))
    def test_kernel_counts_bit_exact(chunk):
        for k in range(10):
            _check_kernel_parity(chunk * 10 + k)

    @pytest.mark.parametrize("chunk", range(6))
    def test_placement_mapping_identical(chunk):
        for k in range(10):
            _check_place_parity(chunk * 10 + k)


# ------------------------------------------------- master-level storms

def _gen_storm(rng):
    """(cluster, ops): arrival/completion/resize script; ~60% of arrivals
    carry fractional demands so the delta path runs fractional too."""
    b = int(rng.integers(2, 6))
    cap = ResourceVector.of(int(rng.integers(8, 17)),
                            int(rng.integers(0, 3)),
                            int(rng.integers(24, 65)))
    cluster = ClusterSpec.homogeneous(b, cap)
    ops, alive, next_id = [], [], 0
    for _ in range(int(rng.integers(10, 19))):
        choices = ["arrive", "arrive"]
        if alive:
            choices += ["complete", "resize"]
        op = choices[int(rng.integers(len(choices)))]
        if op == "arrive":
            if rng.random() < 0.6:
                dem = ResourceVector.of(
                    round(float(rng.uniform(0.3, 3.5)), 2),
                    float(rng.integers(0, 2)),
                    round(float(rng.uniform(0.5, 9.0)), 1))
            else:
                dem = ResourceVector.of(int(rng.integers(1, 4)),
                                        int(rng.integers(0, 2)),
                                        int(rng.integers(1, 10)))
            n_min = int(rng.integers(1, 3))
            spec = ApplicationSpec(f"a{next_id}", "x", dem,
                                   int(rng.integers(1, 4)),
                                   n_min + int(rng.integers(0, 7)), n_min)
            next_id += 1
            alive.append(spec.app_id)
            ops.append(("arrive", spec))
        elif op == "complete":
            ops.append(("complete",
                        alive.pop(int(rng.integers(len(alive))))))
        else:
            lo = int(rng.integers(1, 4))
            ops.append(("resize", alive[int(rng.integers(len(alive)))],
                        lo, lo + int(rng.integers(0, 7))))
    return cluster, ops


def _apply(master, op):
    if op[0] == "arrive":
        return master.on_arrival((op[1],))
    if op[0] == "complete":
        return master.on_completion(op[1])
    return master.on_resize(op[1], op[2], op[3])


def _check_master_storm(seed: int) -> None:
    rng = np.random.default_rng(seed)
    cluster, ops = _gen_storm(rng)
    masters = {}
    for be in ("numpy", "jax"):
        cfg = OptimizerConfig(0.2, 0.2, incremental=True, soa=True,
                              backend=be)
        masters[be] = DormMaster(cluster, "greedy", cfg,
                                 protocol=RecordingProtocol())
    for op in ops:
        ref = _apply(masters["numpy"], op)
        got = _apply(masters["jax"], op)
        assert (ref is None) == (got is None), (seed, op)
        if ref is None:
            continue
        assert got.allocation.app_ids == ref.allocation.app_ids, (seed, op)
        np.testing.assert_array_equal(got.allocation.x, ref.allocation.x,
                                      err_msg=f"seed={seed} op={op}")
        assert got.adjusted_app_ids == ref.adjusted_app_ids, (seed, op)
        assert got.started_app_ids == ref.started_app_ids, (seed, op)
        assert got.pending_app_ids == ref.pending_app_ids, (seed, op)
        assert got.utilization == pytest.approx(ref.utilization, abs=1e-9)
        assert got.fairness_loss == pytest.approx(ref.fairness_loss,
                                                  abs=1e-9)
    # Same control flow, not just the same answers.
    o_ref, o_jax = masters["numpy"].optimizer, masters["jax"].optimizer
    assert o_jax.delta_solves == o_ref.delta_solves, seed
    assert o_jax.full_solves == o_ref.full_solves, seed


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2 ** 32 - 1))
    @settings(max_examples=N_MASTER, deadline=None)
    def test_master_storms_bit_exact_across_backends(seed):
        _check_master_storm(seed)
else:                                                  # pragma: no cover
    @pytest.mark.parametrize("chunk", range(4))
    def test_master_storms_bit_exact_across_backends(chunk):
        for k in range(2):
            _check_master_storm(chunk * 2 + k)


def test_jax_backend_books_compile_time():
    """First-touch jit compiles are accounted in backend.compile_s and
    surfaced by DormMaster.backend_compile_s, not in steady-state time."""
    rng = np.random.default_rng(7)
    cluster, ops = _gen_storm(rng)
    cfg = OptimizerConfig(0.2, 0.2, incremental=True, soa=True,
                          backend="jax")
    m = DormMaster(cluster, "greedy", cfg, protocol=RecordingProtocol())
    for op in ops:
        _apply(m, op)
    assert m.backend_compile_s >= 0.0
    assert m.backend_compile_s == pytest.approx(m.optimizer.backend.compile_s)
    assert "backend_compile" in m.phase_breakdown()
