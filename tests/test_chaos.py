"""Directed fault-injection tests (PR 8).

Covers the chaos engine's deterministic pieces -- schedule generation,
CSV round-trip, capacity rescaling, state fast-mutations -- plus the
recovery semantics of DormMaster and both baselines on hand-built
scenarios, the absorber interaction on a mixed failure flood, and the
reproducibility contract (SimResult carries chaos seed + config hash;
the same artifact replays bit-exact).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (AbsorberConfig, ApplicationSpec, ChaosConfig,
                        ChaosMonitor, ClusterRuntime, ClusterSpec,
                        ClusterState, DormMaster, DRFScheduler,
                        OptimizerConfig, Reallocated, RecordingProtocol,
                        Resize, ResourceVector, SlaveDegraded, SlaveFailed,
                        SlaveRestored, SlaveSpec, StaticScheduler, Storm,
                        TraceConfig,
                        WorkloadApp, chaos_config_hash, chaos_from_csv,
                        chaos_schedule, chaos_to_csv, generate_trace,
                        heterogeneous_cluster, scale_cluster,
                        ReplayLoadSignal, SLOMonitor,
                        forced_churn_attribution)

CFG = ChaosConfig(seed=11, crashes_per_day=12.0, rack_size=2,
                  crash_restore_s=1800.0, drains_per_day=4.0,
                  straggler_frac=0.25, degrade_factor=0.5,
                  degrade_duration_s=900.0)


def _master(cluster, **kw):
    cfg = OptimizerConfig(0.2, 0.2, **kw)
    return DormMaster(cluster, "greedy", cfg, protocol=RecordingProtocol())


def _spec(app_id, cpu=2, mem=8, n_min=1, n_max=4, **kw):
    return ApplicationSpec(app_id, "x", ResourceVector.of(cpu, 0, mem),
                           1, n_max, n_min, **kw)


# ---------------------------------------------------------------- schedule

def test_chaos_schedule_is_deterministic():
    cluster = heterogeneous_cluster(20, seed=3)
    a = chaos_schedule(CFG, cluster, 24 * 3600.0)
    b = chaos_schedule(CFG, cluster, 24 * 3600.0)
    assert a == b
    assert a, "non-zero rates must yield events"
    ts = [e.t for e in a]
    assert ts == sorted(ts)
    assert chaos_config_hash(CFG) == chaos_config_hash(
        ChaosConfig(**dataclasses.asdict(CFG)))
    assert chaos_config_hash(CFG) != chaos_config_hash(
        dataclasses.replace(CFG, seed=12))


def test_chaos_schedule_restores_follow_failures():
    cluster = heterogeneous_cluster(20, seed=3)
    events = chaos_schedule(CFG, cluster, 24 * 3600.0)
    down_at = {}
    for ev in events:
        if isinstance(ev, SlaveFailed):
            down_at[ev.slave_id] = ev.t
        elif isinstance(ev, SlaveRestored) and ev.slave_id in down_at:
            assert ev.t > down_at.pop(ev.slave_id)
    # A degraded slave is never one the crash/drain stream touched.
    crashed = {e.slave_id for e in events if isinstance(e, SlaveFailed)}
    degraded = {e.slave_id for e in events if isinstance(e, SlaveDegraded)}
    assert not (crashed & degraded)


def test_chaos_schedule_respects_t_start():
    cluster = heterogeneous_cluster(10, seed=0)
    cfg = dataclasses.replace(CFG, t_start_s=7200.0)
    events = chaos_schedule(cfg, cluster, 24 * 3600.0)
    assert all(e.t >= 7200.0 for e in events)


def test_chaos_csv_round_trip(tmp_path):
    cluster = heterogeneous_cluster(16, seed=1)
    events = chaos_schedule(CFG, cluster, 24 * 3600.0)
    text = chaos_to_csv(events)
    back = chaos_from_csv(text)
    assert back == sorted(events, key=lambda e: e.t)
    p = tmp_path / "incidents.csv"
    p.write_text(text)
    assert chaos_from_csv(str(p)) == back
    with pytest.raises(ValueError, match="unknown chaos kind"):
        chaos_from_csv("t_s,kind,slave_id,factor\n1.0,exploded,s0,\n")


# ----------------------------------------------------------- scale_cluster

def test_scale_cluster_preserves_ids_and_scales_capacity():
    base = heterogeneous_cluster(6, seed=2)
    scale = np.array([1.0, 0.0, 0.5, 1.0, 1.0, 0.25])
    scaled = scale_cluster(base, scale)
    assert tuple(s.slave_id for s in scaled.slaves) == \
        tuple(s.slave_id for s in base.slaves)
    np.testing.assert_allclose(
        scaled.capacity_matrix(),
        base.capacity_matrix() * scale[:, None])
    # Healthy slaves keep their original SlaveSpec objects (cache reuse).
    assert scaled.slaves[0] is base.slaves[0]
    assert scaled.slaves[1] is not base.slaves[1]
    healthy = scale_cluster(base, np.ones(6))
    np.testing.assert_array_equal(healthy.capacity_matrix(),
                                  base.capacity_matrix())


def test_state_set_cluster_adjusts_free_and_guards_ids():
    base = ClusterSpec.homogeneous(3, ResourceVector.of(8, 0, 32))
    st = ClusterState(base)
    st.admit(_spec("a"))
    st.place("a", np.array([2, 1, 0]))
    free_before = st.free.copy()
    scaled = scale_cluster(base, [1.0, 0.5, 1.0])
    st.set_cluster(scaled)
    np.testing.assert_array_equal(st.cap, scaled.capacity_matrix())
    delta = scaled.capacity_matrix() - base.capacity_matrix()
    np.testing.assert_allclose(st.free, free_before + delta)
    np.testing.assert_allclose(st.total_cap,
                               scaled.capacity_matrix().sum(axis=0))
    wrong = ClusterSpec(
        resource_types=base.resource_types,
        slaves=tuple(SlaveSpec(f"other-{j}", s.capacity)
                     for j, s in enumerate(base.slaves)))
    with pytest.raises(ValueError, match="slave ids"):
        st.set_cluster(wrong)


# --------------------------------------------------- DormMaster recovery

@pytest.mark.parametrize("soa", [True, False])
def test_master_failure_displaces_and_replaces(soa):
    # 3 roomy slaves; the app fits on any one of them, so losing its host
    # must re-place it immediately in the SAME recovery solve.
    cluster = ClusterSpec.homogeneous(3, ResourceVector.of(16, 0, 64))
    m = _master(cluster, soa=soa)
    m.on_arrival((_spec("a", n_min=2, n_max=2),))
    row = (m.state.placement("a") if m.state is not None
           else m._placements["a"])
    host = int(np.flatnonzero(row)[0])
    sid = cluster.slaves[host].slave_id
    res = m.on_slave_failed(sid)
    assert res is not None
    assert res.displaced_app_ids == ("a",)
    assert res.forced_adjusted_app_ids == ("a",)
    assert "a" in res.adjusted_app_ids
    assert res.parked_app_ids == ()
    i = res.allocation.app_ids.index("a")
    assert res.allocation.x[i, host] == 0
    assert int(res.allocation.x[i].sum()) == 2
    # The dead slave's capacity is fenced in the effective spec.
    assert m.cluster.capacity_matrix()[host].sum() == 0.0
    # Double failure of the same slave is a no-op.
    assert m.on_slave_failed(sid) is None
    assert m.on_slave_failed("no-such-slave") is None


@pytest.mark.parametrize("soa", [True, False])
def test_master_parks_unplaceable_then_recovers_on_restore(soa):
    # Two slaves; the app needs BOTH (n_min 8, 4 per slave max). Losing
    # one makes it unplaceable -> parked. Restoring re-places it.
    cluster = ClusterSpec.homogeneous(2, ResourceVector.of(8, 0, 32))
    m = _master(cluster, soa=soa)
    m.on_arrival((_spec("a", n_min=8, n_max=8),))
    assert m.containers_of("a") == 8
    res = m.on_slave_failed("slave-0")
    assert res is not None
    assert res.parked_app_ids == ("a",)
    assert "a" in m.pending and m.containers_of("a") == 0
    assert res.changed_counts.get("a") == 0
    back = m.on_slave_restored("slave-0")
    assert back is not None
    assert "a" in back.started_app_ids
    assert m.containers_of("a") == 8 and "a" not in m.pending


@pytest.mark.parametrize("soa", [True, False])
def test_master_degrade_shrinks_within_bounds(soa):
    cluster = ClusterSpec.homogeneous(2, ResourceVector.of(8, 0, 32))
    m = _master(cluster, soa=soa)
    m.on_arrival((_spec("a", cpu=2, mem=8, n_min=2, n_max=8),))
    assert m.containers_of("a") == 8
    res = m.on_slave_degraded("slave-1", factor=0.5)
    assert res is not None
    n = m.containers_of("a")
    assert 2 <= n <= 8
    used = sum(m.specs["a"].demand.as_array() * n)
    assert used <= m.cluster.capacity_matrix().sum() + 1e-9
    res2 = m.on_slave_restored("slave-1")
    assert res2 is not None
    assert m.containers_of("a") == 8


def test_master_on_batch_processes_chaos_before_completions():
    # Satellite: a flood carrying {SlaveFailed, Completion of an app on
    # that slave, Resize of another app on it} must drop the dead slave's
    # rows FIRST, then apply the merged completion + resize -- one solve,
    # consistent capacity, no phantom containers on the dead slave.
    cluster = ClusterSpec.homogeneous(3, ResourceVector.of(8, 0, 32))
    m = _master(cluster)
    m.on_arrival((_spec("a", n_min=3, n_max=3),
                  _spec("b", n_min=3, n_max=3),
                  _spec("c", n_min=2, n_max=6)))
    res = m.on_batch(("a",), (("c", 1, 6),), (),
                     chaos=(SlaveFailed(100.0, "slave-0"),))
    assert res is not None
    assert "a" not in m.specs
    assert m.cluster.capacity_matrix()[0].sum() == 0.0
    for app_id in ("b", "c"):
        i = res.allocation.app_ids.index(app_id)
        assert res.allocation.x[i, 0] == 0, "row on dead slave survived"
        spec = m.specs[app_id]
        assert spec.n_min <= int(res.allocation.x[i].sum()) <= spec.n_max
    assert (m.specs["c"].n_min, m.specs["c"].n_max) == (1, 6)
    assert "a" not in res.parked_app_ids        # completed, not parked
    # Forced churn only covers apps the failure displaced and that are
    # still admitted; the completed app is not adjusted.
    assert "a" not in res.adjusted_app_ids
    assert set(res.forced_adjusted_app_ids) <= {"b", "c"}


# ----------------------------------------------------- baseline degrading

def test_static_scheduler_survives_slave_loss():
    cluster = ClusterSpec.homogeneous(2, ResourceVector.of(8, 0, 32))
    s = StaticScheduler(cluster, {"a": 4, "b": 4})
    s.on_arrival((_spec("a", n_min=4, n_max=4),))
    s.on_arrival((_spec("b", n_min=4, n_max=4),))
    hosts_a = s.placements["a"].copy()
    victim = int(np.flatnonzero(hosts_a)[0])
    sid = cluster.slaves[victim].slave_id
    res = s._chaos(sid, 0.0)
    assert res is not None
    assert "a" in res.displaced_app_ids
    assert np.all(s.slave_free >= -1e-9), "free capacity went negative"
    assert np.all(s.slave_free <= s.slave_cap + 1e-9), \
        "freed more capacity than exists (double count)"
    assert s.slave_cap[victim].sum() == 0.0
    # Displaced apps re-queue (FCFS) or restart; never silently vanish.
    for a in res.displaced_app_ids:
        assert (a in s.placements) or (a in s.queue)
    assert res.forced_adjusted_app_ids == res.adjusted_app_ids
    # Restore brings capacity back and re-admits the queue.
    res2 = s.on_slave_restored(sid)
    assert res2 is not None
    assert not s.queue
    assert set(s.placements) == {"a", "b"}
    np.testing.assert_allclose(s.slave_cap, s._base_cap)


def test_static_scheduler_double_failure_is_noop():
    cluster = ClusterSpec.homogeneous(2, ResourceVector.of(8, 0, 32))
    s = StaticScheduler(cluster, {})
    assert s.on_slave_failed("slave-0") is not None
    assert s.on_slave_failed("slave-0") is None
    assert s.on_slave_failed("bogus") is None


def test_drf_scheduler_survives_slave_loss():
    cluster = ClusterSpec.homogeneous(2, ResourceVector.of(8, 0, 32))
    s = DRFScheduler(cluster)
    s.on_arrival((_spec("a"), _spec("b")))
    displaced_hosts = {a for a, row in s.placements.items() if row[0] > 0}
    res = s.on_slave_failed("slave-0")
    assert res is not None
    assert set(res.displaced_app_ids) == displaced_hosts
    assert set(res.forced_adjusted_app_ids) <= set(res.adjusted_app_ids)
    # The repack must respect the reduced capacity: nothing on slave 0.
    for a, row in s.placements.items():
        assert row[0] == 0, a
    cap = s.cluster.capacity_matrix()
    used = np.zeros_like(cap)
    for a, row in s.placements.items():
        used += row[:, None] * s.specs[a].demand.as_array()[None, :]
    assert np.all(used <= cap + 1e-9)
    assert s.on_slave_failed("slave-0") is None       # no-op repeat
    res2 = s.on_slave_restored("slave-0")
    assert res2 is not None
    np.testing.assert_array_equal(s.cluster.capacity_matrix(),
                                  cluster.capacity_matrix())


# ------------------------------------------------- runtime + reproducibility

def _wl(n=8, seed=7):
    return generate_trace(TraceConfig(n_apps=n, seed=seed,
                                      mean_interarrival_s=400.0))


def test_runtime_records_chaos_seed_and_hash():
    cluster = heterogeneous_cluster(12, seed=3)
    m = _master(cluster)
    rt = ClusterRuntime(m, horizon_s=12 * 3600.0, chaos=CFG)
    res = rt.run(_wl())
    assert res.chaos_seed == CFG.seed
    assert res.chaos_config_hash == chaos_config_hash(CFG)
    healthy = ClusterRuntime(_master(cluster), horizon_s=12 * 3600.0)
    res_h = healthy.run(_wl())
    assert res_h.chaos_seed is None and res_h.chaos_config_hash is None
    assert res_h.total_forced_adjustments == 0


def test_chaos_replay_is_bit_exact():
    """Same config + cluster + horizon => identical timeline (the
    reproducibility contract behind SimResult.chaos_seed/.chaos_config_hash:
    the artifact alone is enough to re-run the failure replay)."""
    cluster = heterogeneous_cluster(12, seed=3)

    def run():
        m = _master(cluster)
        rt = ClusterRuntime(m, horizon_s=12 * 3600.0, chaos=CFG)
        allocs = []
        rt.bus.subscribe(Reallocated,
                         lambda e: allocs.append(
                             (e.t, e.result.allocation.app_ids,
                              e.result.allocation.x.copy())))
        return rt.run(_wl()), allocs

    res_a, al_a = run()
    res_b, al_b = run()
    assert res_a.samples == res_b.samples
    assert len(al_a) == len(al_b)
    for (t1, i1, x1), (t2, i2, x2) in zip(al_a, al_b):
        assert t1 == t2 and i1 == i2
        np.testing.assert_array_equal(x1, x2)


def test_chaos_requires_cluster_capable_policy():
    class Bare:
        def on_arrival(self, specs): return None
        def on_completion(self, app_id): return None
        def on_resize(self, app_id, n_min=None, n_max=None): return None
        def on_tick(self, t): return None
        def containers_of(self, app_id): return 0
    rt = ClusterRuntime(Bare(), chaos=CFG)
    with pytest.raises(ValueError, match="cluster"):
        rt.run([])


def test_absorber_coalesces_rack_failure_flood():
    # A rack failure (2 slaves at one instant) + a same-instant completion
    # and resize coalesce into ONE Storm pass carrying the chaos events.
    cluster = ClusterSpec.homogeneous(4, ResourceVector.of(8, 0, 32))
    t_flood = 500.0
    # a runs 2 containers on serial_work 2*t_flood => completes AT t_flood.
    spec_a = _spec("a", n_min=2, n_max=2, submit_time=0.0,
                   serial_work=2 * t_flood)
    spec_b = _spec("b", n_min=2, n_max=6, submit_time=0.0,
                   serial_work=80_000.0)
    wl = [WorkloadApp(spec=spec_a, class_index=0, base_duration_s=t_flood),
          WorkloadApp(spec=spec_b, class_index=0,
                      base_duration_s=80_000.0)]
    m = _master(cluster)
    rt = ClusterRuntime(m, horizon_s=12 * 3600.0,
                        absorber=AbsorberConfig())
    rt.inject(SlaveFailed(t_flood, "slave-0"),
              SlaveFailed(t_flood, "slave-1"),
              Resize(t_flood, "b", 2, 4))
    storms = []
    rt.bus.subscribe(Storm, storms.append)
    reallocs = []
    rt.bus.subscribe(Reallocated, reallocs.append)
    res = rt.run(wl)
    flood = [s for s in storms if s.t == t_flood]
    assert len(flood) == 1, storms
    st_ = flood[0]
    assert len(st_.chaos) == 2 and len(st_.resizes) == 1
    assert "a" in st_.completions
    # One merged recovery solve handled the whole flood; b's rows on the
    # dead slaves are gone and it landed back within its (new) bounds.
    for j in (0, 1):
        assert m.cluster.capacity_matrix()[j].sum() == 0.0
    at_flood = [e.result for e in reallocs if e.t == t_flood]
    assert len(at_flood) == 1
    r = at_flood[0]
    assert "b" in r.displaced_app_ids
    i = r.allocation.app_ids.index("b")
    assert r.allocation.x[i, 0] == 0 and r.allocation.x[i, 1] == 0
    assert 2 <= int(r.allocation.x[i].sum()) <= 4
    assert res.total_forced_adjustments >= 1


def test_chaos_monitor_accounting():
    base = ClusterSpec.homogeneous(4, ResourceVector.of(8, 0, 32))
    mon = ChaosMonitor(base)
    # Hand-driven integral: slave 0 fully down for 100 s on a 4-slave,
    # 2-positive-resource cluster -> (1/4 + 1/4) * 100 = 50 units.
    mon._on_chaos(SlaveFailed(100.0, "slave-0"))
    mon._on_chaos(SlaveRestored(200.0, "slave-0"))
    mon.finalize(1000.0)
    assert mon.lost_capacity_seconds == pytest.approx(50.0)
    assert mon.counts["failed"] == 1 and mon.counts["restored"] == 1
    mon.finalize(1000.0)                  # idempotent
    assert mon.lost_capacity_seconds == pytest.approx(50.0)
    assert mon.replaced_fraction == 1.0   # nothing displaced
    assert mon.median_recovery_s() is None


def test_chaos_monitor_end_to_end_recovery():
    cluster = heterogeneous_cluster(24, seed=3)
    cfg = ChaosConfig(seed=2, crashes_per_day=30.0, rack_size=2,
                      crash_restore_s=1800.0)
    m = _master(cluster)
    rt = ClusterRuntime(m, horizon_s=12 * 3600.0, chaos=cfg)
    mon = ChaosMonitor(cluster).attach(rt)
    rt.run(_wl(n=10))
    mon.finalize(12 * 3600.0)
    s = mon.summary()
    assert s["events"]["failed"] > 0
    assert s["lost_capacity_seconds"] > 0.0
    assert s["forced_adjustments"] == rt.total_forced_adjustments
    if s["displaced"]:
        assert s["replaced"] + s["unresolved_displaced"] == s["displaced"]


def test_slo_monitor_reports_forced_churn_under_chaos():
    # Autoscale interaction: the serving-SLO panel splits Eq-4 churn by
    # compulsion, so overload/lag numbers can be read against the
    # capacity the failures took away.
    cluster = heterogeneous_cluster(24, seed=3)
    cfg = ChaosConfig(seed=2, crashes_per_day=30.0, rack_size=2,
                      crash_restore_s=1800.0)
    m = _master(cluster)
    rt = ClusterRuntime(m, horizon_s=12 * 3600.0, chaos=cfg)
    wl = _wl(n=10)
    slo = SLOMonitor({w.spec.app_id: ReplayLoadSignal([0.0], [1.0])
                      for w in wl}).attach(rt)
    rt.run(wl)
    comp = slo.summary(12 * 3600.0)["churn_by_compulsion"]
    assert comp == forced_churn_attribution(slo.reallocated)
    assert comp["forced"] == rt.total_forced_adjustments
    assert comp["displaced"] >= comp["parked"] >= 0
    total = sum(slo.summary(12 * 3600.0)["churn_by_trigger"].values())
    assert comp["forced"] + comp["voluntary"] == total
