"""Property-based chaos-storm suite (PR 8).

Random interleavings of Arrival / Completion / Resize / SlaveFailed /
SlaveDrained / SlaveDegraded / SlaveRestored events driven through FOUR
DormMaster configurations simultaneously (SoA/legacy engine x
incremental/full re-solve). Invariants, after every single event:

  * effective per-slave capacity is never exceeded (a dead slave hosts
    nothing; a degraded slave hosts at most its fraction),
  * every PLACED app holds n_min <= count <= n_max (displaced apps that
    cannot reach n_min are parked, never left half-placed),
  * no work is lost beyond Eq-4: every displaced app is either re-placed
    (forced adjustment, charged to the Eq-4 overhead) or parked into the
    pending queue -- it never silently vanishes,
  * the four engines are bit-exact event-for-event.

Runtime-level properties mirror the absorber doctrine: with NO
same-timestamp ties, an absorber-attached chaos run is bit-exact vs an
absorber-free run; an absorbed failure flood (correlated rack loss) is
bit-exact across engines and backends (jax when available).

Runs under hypothesis when available; falls back to a seeded-random
sweep of the same checks otherwise."""
import dataclasses

import numpy as np
import pytest

from repro.core import (AbsorberConfig, ApplicationSpec, ChaosConfig,
                        ClusterRuntime, ClusterSpec, DormMaster,
                        OptimizerConfig, Reallocated, RecordingProtocol,
                        Resize, ResourceVector, SlaveDegraded, SlaveDrained,
                        SlaveFailed, SlaveRestored, TraceConfig,
                        backend_available, generate_trace,
                        heterogeneous_cluster)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

HAVE_JAX = backend_available("jax")

THETAS = ((0.2, 0.2), (1.0, 1.0), (0.1, 0.3))


def _masters(cluster, theta):
    out = {}
    for soa in (True, False):
        for inc in (True, False):
            cfg = OptimizerConfig(*theta, incremental=inc, soa=soa)
            out[(soa, inc)] = DormMaster(cluster, "greedy", cfg,
                                         protocol=RecordingProtocol())
    return out


def _gen_ops(rng):
    """Random chaos-heavy event script: (cluster, theta, ops)."""
    b = int(rng.integers(2, 6))
    cap = ResourceVector.of(int(rng.integers(6, 14)),
                            int(rng.integers(0, 3)),
                            int(rng.integers(16, 49)))
    cluster = ClusterSpec.homogeneous(b, cap)
    theta = THETAS[int(rng.integers(len(THETAS)))]

    ops = []
    alive = []
    down = set()
    next_id = 0
    for _ in range(int(rng.integers(10, 21))):
        choices = ["arrive", "fail", "degrade"]
        if alive:
            choices += ["complete", "resize"]
        if down:
            choices += ["restore", "restore"]
        op = choices[int(rng.integers(len(choices)))]
        if op == "arrive":
            n_min = int(rng.integers(1, 3))
            n_max = n_min + int(rng.integers(0, 7))
            spec = ApplicationSpec(
                f"a{next_id}", "x",
                ResourceVector.of(int(rng.integers(1, 4)),
                                  int(rng.integers(0, 2)),
                                  int(rng.integers(1, 13))),
                int(rng.integers(1, 4)), n_max, n_min)
            next_id += 1
            alive.append(spec.app_id)
            ops.append(("arrive", spec))
        elif op == "complete":
            app = alive.pop(int(rng.integers(len(alive))))
            ops.append(("complete", app))
        elif op == "resize":
            app = alive[int(rng.integers(len(alive)))]
            lo = int(rng.integers(1, 4))
            ops.append(("resize", app, lo, lo + int(rng.integers(0, 8))))
        elif op == "fail":
            j = int(rng.integers(b))
            down.add(j)
            kind = "fail" if rng.random() < 0.7 else "drain"
            ops.append((kind, f"slave-{j}"))
        elif op == "degrade":
            j = int(rng.integers(b))
            down.add(j)
            f = float(rng.choice([0.25, 0.5, 0.75]))
            ops.append(("degrade", f"slave-{j}", f))
        else:  # restore
            j = down.pop() if rng.random() < 0.8 else int(rng.integers(b))
            ops.append(("restore", f"slave-{j}"))
    return cluster, theta, ops


def _apply(master, op):
    kind = op[0]
    if kind == "arrive":
        return master.on_arrival((op[1],))
    if kind == "complete":
        return master.on_completion(op[1])
    if kind == "resize":
        return master.on_resize(op[1], op[2], op[3])
    if kind == "fail":
        return master.on_slave_failed(op[1])
    if kind == "drain":
        return master.on_slave_drained(op[1])
    if kind == "degrade":
        return master.on_slave_degraded(op[1], op[2])
    return master.on_slave_restored(op[1])


def _check_invariants(master, res):
    """Capacity / bounds / no-lost-work invariants from the master's own
    (post-event) view, against the EFFECTIVE cluster spec."""
    cap = master.cluster.capacity_matrix()
    used = np.zeros_like(cap, dtype=np.float64)
    placed = set()
    for app_id in list(master.partitions):
        spec = master.specs[app_id]
        if master.state is not None:
            row = master.state.placement(app_id)
        else:
            row = master._placements[app_id]
        count = int(row.sum())
        placed.add(app_id)
        assert spec.n_min <= count <= spec.n_max, \
            f"{app_id}: count {count} outside [{spec.n_min}, {spec.n_max}]"
        used += row[:, None] * spec.demand.as_array()[None, :]
    assert np.all(used <= cap + 1e-6), "effective capacity exceeded"
    # No app lost beyond Eq-4: every admitted app is placed or pending,
    # and every displaced app in this result was re-placed, parked, or
    # completed -- never dropped from the universe.
    assert placed | set(master.pending) == set(master.specs)
    if res is not None:
        assert set(res.forced_adjusted_app_ids) <= set(res.adjusted_app_ids)
        assert set(res.parked_app_ids) <= set(master.pending)
        for a in res.displaced_app_ids:
            assert (a in placed) or (a in master.pending) \
                or (a not in master.specs), f"{a} silently vanished"


def _check_storm(seed: int) -> None:
    rng = np.random.default_rng(seed)
    cluster, theta, ops = _gen_ops(rng)
    masters = _masters(cluster, theta)
    ref_key = (True, True)
    for op in ops:
        results = {}
        for key, m in masters.items():
            results[key] = _apply(m, op)
            _check_invariants(m, results[key])
        ref = results[ref_key]
        for key, res in results.items():
            if key == ref_key:
                continue
            assert (res is None) == (ref is None), (op, key)
            if ref is None:
                continue
            assert res.allocation.app_ids == ref.allocation.app_ids, (op, key)
            np.testing.assert_array_equal(res.allocation.x, ref.allocation.x,
                                          err_msg=f"{op} {key}")
            assert res.adjusted_app_ids == ref.adjusted_app_ids, (op, key)
            assert res.forced_adjusted_app_ids == \
                ref.forced_adjusted_app_ids, (op, key)
            assert res.displaced_app_ids == ref.displaced_app_ids, (op, key)
            assert res.parked_app_ids == ref.parked_app_ids, (op, key)
            assert res.started_app_ids == ref.started_app_ids, (op, key)
            assert res.pending_app_ids == ref.pending_app_ids, (op, key)
            assert res.changed_counts == ref.changed_counts, (op, key)
            assert res.utilization == pytest.approx(ref.utilization,
                                                    abs=1e-9)
            assert res.fairness_loss == pytest.approx(ref.fairness_loss,
                                                      abs=1e-9)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2 ** 32 - 1))
    @settings(max_examples=120, deadline=None)
    def test_chaos_storm_engines_bit_exact(seed):
        _check_storm(seed)
else:
    @pytest.mark.parametrize("chunk", range(8))
    def test_chaos_storm_engines_bit_exact(chunk):
        # Seeded fallback: same check, 8 chunks x 15 seeds = 120 examples.
        for k in range(15):
            _check_storm(chunk * 15 + k)


# ------------------------------------------- runtime-level chaos timelines

def _chaos_cfg(seed):
    return ChaosConfig(seed=int(seed) % 1009, crashes_per_day=20.0,
                       rack_size=2, crash_restore_s=1800.0,
                       drains_per_day=4.0, straggler_frac=0.15,
                       degrade_factor=0.5, degrade_duration_s=1800.0)


def _run(cluster, wl, chaos, absorber=None, soa=True, incremental=True,
         backend="numpy"):
    cfg = OptimizerConfig(0.2, 0.2, incremental=incremental, soa=soa,
                          backend=backend)
    m = DormMaster(cluster, "greedy", cfg, protocol=RecordingProtocol())
    rt = ClusterRuntime(m, horizon_s=12 * 3600.0, chaos=chaos,
                        absorber=absorber)
    allocs = []
    rt.bus.subscribe(Reallocated,
                     lambda e: allocs.append((e.t,
                                              e.result.allocation.app_ids,
                                              e.result.allocation.x.copy())))
    res = rt.run(wl)
    return res, allocs, rt


def _scenario(seed):
    rng = np.random.default_rng(seed)
    cluster = heterogeneous_cluster(int(rng.integers(8, 16)),
                                    seed=int(seed) % 17)
    wl = generate_trace(TraceConfig(n_apps=int(rng.integers(8, 16)),
                                    seed=seed, mean_interarrival_s=400.0,
                                    burst_prob=0.0))
    return cluster, wl


def _assert_timelines_equal(a, b, ctx=""):
    (res_a, al_a, _), (res_b, al_b, _) = a, b
    assert len(al_a) == len(al_b), ctx
    for (t1, ids1, x1), (t2, ids2, x2) in zip(al_a, al_b):
        assert t1 == t2 and ids1 == ids2, ctx
        np.testing.assert_array_equal(x1, x2, err_msg=ctx)
    assert res_a.durations() == res_b.durations(), ctx
    assert res_a.total_forced_adjustments == \
        res_b.total_forced_adjustments, ctx
    assert len(res_a.samples) == len(res_b.samples), ctx
    for sa, sb in zip(res_a.samples, res_b.samples):
        assert sa.t == sb.t and sa.running == sb.running, ctx
        assert sa.pending == sb.pending, ctx
        assert sa.adjustment_overhead == sb.adjustment_overhead, ctx
        assert sa.forced_adjustments == sb.forced_adjustments, ctx
        assert sa.utilization == pytest.approx(sb.utilization, abs=1e-9)
        assert sa.fairness_loss == pytest.approx(sb.fairness_loss, abs=1e-9)


def _check_runtime_chaos_engines(seed):
    """SoA/legacy x incremental/full timelines identical under a seeded
    failure replay (per-event path, rack floods processed one by one)."""
    cluster, wl = _scenario(seed)
    chaos = _chaos_cfg(seed)
    runs = {(soa, inc): _run(cluster, wl, chaos, soa=soa, incremental=inc)
            for soa in (True, False) for inc in (True, False)}
    ref = runs[(True, True)]
    assert ref[0].chaos_seed == chaos.seed
    for key, run in runs.items():
        if key != (True, True):
            _assert_timelines_equal(ref, run, f"seed={seed} {key}")


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2 ** 32 - 1))
    @settings(max_examples=8, deadline=None)
    def test_runtime_chaos_timelines_identical_across_engines(seed):
        _check_runtime_chaos_engines(seed)
else:
    @pytest.mark.parametrize("seed", range(5))
    def test_runtime_chaos_timelines_identical_across_engines(seed):
        _check_runtime_chaos_engines(seed)


def _check_no_ties_absorber_bit_exact(seed):
    """rack_size=1 + continuous trace times: no two events share an
    instant, so the absorber must not change the timeline at all."""
    cluster, wl = _scenario(seed)
    chaos = dataclasses.replace(_chaos_cfg(seed), rack_size=1)
    base = _run(cluster, wl, chaos)
    absorbed = _run(cluster, wl, chaos, absorber=AbsorberConfig())
    _assert_timelines_equal(base, absorbed, f"seed={seed}")
    assert absorbed[2].absorber_stats["absorbed_events"] == 0


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2 ** 32 - 1))
    @settings(max_examples=8, deadline=None)
    def test_chaos_absorber_without_ties_is_bit_exact(seed):
        _check_no_ties_absorber_bit_exact(seed)
else:
    @pytest.mark.parametrize("seed", range(5))
    def test_chaos_absorber_without_ties_is_bit_exact(seed):
        _check_no_ties_absorber_bit_exact(seed)


def _check_absorbed_chaos_engines(seed):
    """Correlated rack loss (rack_size >= 2) coalesces; the absorbed
    recovery timeline is bit-exact across engines."""
    cluster, wl = _scenario(seed)
    chaos = dataclasses.replace(_chaos_cfg(seed), rack_size=3,
                                crashes_per_day=30.0)
    runs = {(soa, inc): _run(cluster, wl, chaos,
                             absorber=AbsorberConfig(), soa=soa,
                             incremental=inc)
            for soa in (True, False) for inc in (True, False)}
    ref = runs[(True, True)]
    assert ref[2].absorber_stats["absorbed_events"] > 0, seed
    for key, run in runs.items():
        if key != (True, True):
            _assert_timelines_equal(ref, run, f"seed={seed} {key}")
        assert run[2].absorber_stats == ref[2].absorber_stats, key


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2 ** 32 - 1))
    @settings(max_examples=6, deadline=None)
    def test_absorbed_chaos_floods_bit_exact_across_engines(seed):
        _check_absorbed_chaos_engines(seed)
else:
    @pytest.mark.parametrize("seed", range(4))
    def test_absorbed_chaos_floods_bit_exact_across_engines(seed):
        _check_absorbed_chaos_engines(seed)


@pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")
@pytest.mark.parametrize("seed", [3, 17])
def test_chaos_timelines_bit_exact_vs_jax_backend(seed):
    cluster, wl = _scenario(seed)
    chaos = _chaos_cfg(seed)
    ref = _run(cluster, wl, chaos)
    jx = _run(cluster, wl, chaos, backend="jax")
    _assert_timelines_equal(ref, jx, f"seed={seed} jax")
    rack = dataclasses.replace(chaos, rack_size=3, crashes_per_day=30.0)
    ref_f = _run(cluster, wl, rack, absorber=AbsorberConfig())
    jx_f = _run(cluster, wl, rack, absorber=AbsorberConfig(), backend="jax")
    assert ref_f[2].absorber_stats["absorbed_events"] > 0, seed
    _assert_timelines_equal(ref_f, jx_f, f"seed={seed} jax absorbed")
