"""Column-generation exact solve (MilpOptimizer behind
`OptimizerConfig.column_generation` / `make_optimizer("colgen")`):

* parity with the monolithic MILP objective on instances small enough to
  solve both ways,
* a certified global optimality gap <= 1% on a >= 5k-variable instance
  (far past the monolithic grid),
* Eq-15/Eq-16 budget compliance against a previous allocation,
* degenerate cases: no apps, a single app, an all-n_min-infeasible
  cluster,
* gap reporting through DormMaster (`ReallocationResult.optimality_gap`,
  `phase_breakdown()['colgen_pricing']`).
"""
import numpy as np
import pytest

from repro.core import (Allocation, ApplicationSpec, ClusterSpec, DormMaster,
                        MilpOptimizer, OptimizerConfig, RecordingProtocol,
                        ResourceVector, adjust_budget, fairness_budget,
                        make_optimizer, resource_utilization,
                        validate_allocation)

pytest.importorskip("scipy")


def _apps(n, seed=0, nmax=8):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        out.append(ApplicationSpec(
            f"a{i}", "x",
            ResourceVector.of(int(rng.integers(1, 4)), 0,
                              int(rng.integers(2, 9))),
            int(rng.integers(1, 3)), nmax, 1))
    return out


def test_make_optimizer_colgen_route():
    opt = make_optimizer("colgen", OptimizerConfig(0.2, 0.2))
    assert isinstance(opt, MilpOptimizer)
    assert opt.cfg.column_generation is True


def test_colgen_matches_monolithic_objective_exactly():
    """On instances small enough for the monolithic MILP, the colgen path
    must land on the SAME utilization objective (several seeds, including
    CPU-saturated selections that need the exact packing repair)."""
    cluster = ClusterSpec.homogeneous(6, ResourceVector.of(16, 0, 64))
    for seed in range(8):
        apps = _apps(8, seed=seed)
        mono = MilpOptimizer(OptimizerConfig(0.2, 0.2,
                                             rolling_horizon_vars=0))
        col = make_optimizer("colgen", OptimizerConfig(0.2, 0.2))
        a_m = mono.solve(apps, cluster, None)
        a_c = col.solve(apps, cluster, None)
        assert a_m is not None and a_c is not None
        assert col.colgen_solves == 1 and col.monolithic_solves == 0
        validate_allocation(a_c, apps, cluster)
        u_m = resource_utilization(a_m, apps, cluster)
        u_c = resource_utilization(a_c, apps, cluster)
        assert u_c == pytest.approx(u_m, abs=1e-9), f"seed={seed}"
        # the report is self-consistent: bound >= objective, gap in [0, 1)
        assert col.last_gap is not None and col.last_gap >= 0.0
        assert col.last_bound >= col.last_objective - 1e-9


def test_colgen_certified_gap_on_5k_variable_instance():
    """2000 apps x 400 slaves (800k x-variables, 16k count-level columns;
    the monolithic grid is intractable): the colgen path must solve
    end-to-end on CPU with a certified global gap <= 1%."""
    cluster = ClusterSpec.homogeneous(400, ResourceVector.of(32, 0, 128))
    apps = _apps(2000, seed=2)
    col = make_optimizer("colgen", OptimizerConfig(0.2, 0.2,
                                                   time_limit_s=60.0))
    alloc = col.solve(apps, cluster, None)
    assert alloc is not None
    validate_allocation(alloc, apps, cluster)
    assert col.colgen_columns >= 5_000
    assert col.last_gap is not None
    assert 0.0 <= col.last_gap <= 0.01
    # the bound really is a bound: no allocation can beat it
    assert col.last_objective <= col.last_bound + 1e-9


def test_colgen_respects_global_budgets_vs_prev():
    """With a previous allocation the result must honor the GLOBAL Eq-15
    and Eq-16 budgets (the count-change flag is exact because unchanged
    apps keep their rows verbatim)."""
    cluster = ClusterSpec.homogeneous(10, ResourceVector.of(16, 0, 64))
    apps = _apps(12, seed=3, nmax=6)
    cfg = OptimizerConfig(0.2, 0.2)
    opt = make_optimizer("colgen", cfg)
    first = opt.solve(apps, cluster, None)
    assert first is not None
    x0 = first.x.copy()
    busy = int(np.argmax(x0.sum(axis=1)))
    x0[busy] = 0
    x0[busy, 0] = 1
    prev = Allocation(first.app_ids, x0)
    second = opt.solve(apps, cluster, prev)
    assert second is not None
    validate_allocation(second, apps, cluster)
    changed = sum(1 for i in range(len(apps))
                  if not np.array_equal(second.x[i], prev.x[i]))
    assert changed <= adjust_budget(cfg, len(apps))
    from repro.core.optimizer import _dominant_coeff
    g = _dominant_coeff(apps, cluster)
    loss = float(np.abs(g * second.x.sum(axis=1)
                        - opt.last_shares_vec).sum())
    assert loss <= fairness_budget(cfg, cluster.m) + 1e-6


def test_colgen_degenerate_cases():
    cluster = ClusterSpec.homogeneous(10, ResourceVector.of(16, 0, 64))
    opt = make_optimizer("colgen", OptimizerConfig(0.2, 0.2))
    # no apps: the empty allocation, proven optimal
    empty = opt.solve([], cluster, None)
    assert empty.x.shape == (0, 10)
    assert opt.last_gap == 0.0
    # a single app with abundant capacity saturates at n_max, gap ~ 0
    (one,) = _apps(1, seed=5)
    alloc = opt.solve([one], cluster, None)
    assert int(alloc.x.sum()) == one.n_max
    assert opt.last_gap is not None and opt.last_gap <= 1e-9
    # an all-n_min-infeasible instance keeps previous allocations
    tiny = ClusterSpec.homogeneous(1, ResourceVector.of(2, 0, 4))
    bad = [ApplicationSpec("big", "x", ResourceVector.of(2, 0, 4),
                           1, 8, 4)]
    assert opt.solve(bad, tiny, None) is None
    assert opt.last_gap is None


def test_colgen_feasible_where_greedy_packer_gives_up():
    """The exact route must not inherit the greedy seed's feasibility: the
    greedy best-fit puts app a's first container on the tight slave and
    strands app b below n_min (GreedyOptimizer returns None), while the
    packing MILP finds the a-on-s2 / b-on-s1 split."""
    from repro.core import GreedyOptimizer, SlaveSpec
    cluster = ClusterSpec(
        resource_types=("cpu", "gpu", "ram"),
        slaves=(SlaveSpec("s1", ResourceVector.of(3, 0, 64)),
                SlaveSpec("s2", ResourceVector.of(4, 0, 64))))
    apps = [
        ApplicationSpec("a", "x", ResourceVector.of(2, 0, 2), 1, 2, 2),
        ApplicationSpec("b", "x", ResourceVector.of(3, 0, 1), 1, 1, 1),
    ]
    assert GreedyOptimizer(OptimizerConfig(0.2, 0.2)).solve(
        apps, cluster, None) is None
    opt = make_optimizer("colgen", OptimizerConfig(0.2, 0.2))
    alloc = opt.solve(apps, cluster, None)
    assert alloc is not None
    validate_allocation(alloc, apps, cluster)
    assert opt.last_gap == pytest.approx(0.0, abs=1e-9)


def test_colgen_gap_flows_through_master_and_phase_breakdown():
    cluster = ClusterSpec.homogeneous(8, ResourceVector.of(16, 0, 64))
    master = DormMaster(cluster, "colgen", OptimizerConfig(0.2, 0.2),
                        protocol=RecordingProtocol())
    res = master.submit_batch(_apps(6, seed=1, nmax=4))
    assert res.optimality_gap is not None
    assert 0.0 <= res.optimality_gap < 1.0
    phases = master.phase_breakdown()
    assert set(phases) == {"drf_refill", "colgen_pricing", "solve",
                           "enforce", "metrics", "backend_compile",
                           "absorb"}
    assert phases["colgen_pricing"] >= 0.0
    # greedy masters certify nothing
    g = DormMaster(cluster, "greedy", OptimizerConfig(0.2, 0.2),
                   protocol=RecordingProtocol())
    assert g.submit_batch(_apps(2, seed=2)).optimality_gap is None
