"""DRF (dominant resource fairness) unit tests, incl. the NSDI'11 example."""
import numpy as np
import pytest

from repro.core import (ApplicationSpec, ClusterSpec, ResourceVector,
                        dominant_share, drf_container_counts, drf_shares)


def _cluster(cpus, gpus, ram, n=1):
    return ClusterSpec.homogeneous(
        n, ResourceVector.of(cpus / n, gpus / n, ram / n))


def test_dominant_share_basic():
    total = np.array([9.0, 0.0, 18.0])
    # 1 container of <1 CPU, 0 GPU, 4 RAM> -> dominant is RAM 4/18
    assert dominant_share(1, np.array([1, 0, 4.0]), total) == pytest.approx(4 / 18)


def test_drf_nsdi_example():
    """Ghodsi et al. example: 9 CPUs / 18 GB; A wants <1 CPU, 4 GB>,
    B wants <3 CPU, 1 GB>. DRF gives A 3 tasks, B 2 tasks."""
    cluster = ClusterSpec.homogeneous(
        1, ResourceVector.of(9, 18), resource_types=("cpu", "ram"))
    a = ApplicationSpec("A", "x", ResourceVector.of(1, 4), 1, 100, 1)
    b = ApplicationSpec("B", "x", ResourceVector.of(3, 1), 1, 100, 1)
    counts = drf_container_counts([a, b], cluster)
    assert counts == {"A": 3, "B": 2}
    shares = drf_shares([a, b], cluster)
    assert shares["A"] == pytest.approx(12 / 18)
    assert shares["B"] == pytest.approx(6 / 9)


def test_weighted_drf_prefers_heavier_weight():
    cluster = ClusterSpec.homogeneous(
        1, ResourceVector.of(16, 16), resource_types=("cpu", "ram"))
    light = ApplicationSpec("L", "x", ResourceVector.of(1, 1), 1, 100, 1)
    heavy = ApplicationSpec("H", "x", ResourceVector.of(1, 1), 3, 100, 1)
    counts = drf_container_counts([light, heavy], cluster)
    assert counts["H"] > counts["L"]
    # weighted shares should end near 1:3
    assert counts["H"] / counts["L"] == pytest.approx(3, rel=0.35)


def test_n_max_saturation_releases_capacity():
    cluster = ClusterSpec.homogeneous(
        1, ResourceVector.of(10, 10), resource_types=("cpu", "ram"))
    small = ApplicationSpec("S", "x", ResourceVector.of(1, 1), 1, 2, 1)
    big = ApplicationSpec("B", "x", ResourceVector.of(1, 1), 1, 100, 1)
    counts = drf_container_counts([small, big], cluster)
    assert counts["S"] == 2          # capped by n_max
    assert counts["B"] == 8          # takes the rest


def test_n_min_guaranteed_first():
    cluster = ClusterSpec.homogeneous(
        1, ResourceVector.of(4, 4), resource_types=("cpu", "ram"))
    apps = [ApplicationSpec(f"a{i}", "x", ResourceVector.of(1, 1), 1, 8, 1)
            for i in range(4)]
    counts = drf_container_counts(apps, cluster)
    assert all(c >= 1 for c in counts.values())
