"""Tests for the extension substrates: Zamba2 shared-block LoRA, the eval
harness, telemetry, and 2D (data x model) elastic partitions."""
import json
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core import (ClusterSimulator, DormMaster, MetricsLogger,
                        OptimizerConfig, RecordingProtocol,
                        generate_workload, paper_testbed)
from repro.data import DataConfig, TokenPipeline
from repro.models import decode_step, init_cache, init_params, loss_fn, prefill
from repro.models.config import ModelConfig
from repro.training import evaluate, make_eval_step

HYB_LORA = ModelConfig(
    "hl", "hybrid", 4, 128, 4, 4, 256, 256, head_dim=32, dtype="float32",
    ssm_state=16, ssm_head_dim=16, ssm_chunk=8, hybrid_attn_every=2,
    shared_lora_rank=8, attn_impl="ref")


# ------------------------------------------------------------------- lora

def test_zamba2_full_config_has_lora():
    assert get_config("zamba2-2.7b").shared_lora_rank == 128
    assert smoke_config("zamba2-2.7b").shared_lora_rank <= 8


def test_lora_params_per_group_and_zero_init_b():
    params = init_params(jax.random.PRNGKey(0), HYB_LORA)
    lora = params["groups"]["shared_lora"]
    assert lora["wq_a"].shape == (2, 128, 8)        # stacked over 2 groups
    assert lora["wq_b"].shape == (2, 8, 4, 32)
    np.testing.assert_array_equal(np.asarray(lora["wq_b"]), 0.0)


def test_lora_prefill_decode_consistent():
    params = init_params(jax.random.PRNGKey(0), HYB_LORA)
    # push B off zero so the adapters actually participate
    params["groups"]["shared_lora"]["wq_b"] = (
        jnp.ones_like(params["groups"]["shared_lora"]["wq_b"]) * 0.02)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, 256)
    _, cache = prefill(params, HYB_LORA, toks[:, :S], S + 1)
    lgA, _ = decode_step(params, HYB_LORA, toks[:, S:S + 1], cache)
    cache2 = init_cache(HYB_LORA, B, S + 1)
    for t in range(S + 1):
        lgB, cache2 = decode_step(params, HYB_LORA, toks[:, t:t + 1], cache2)
    assert float(jnp.abs(lgA - lgB).max()) < 2e-3


def test_lora_changes_function():
    params = init_params(jax.random.PRNGKey(0), HYB_LORA)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
    batch = {"tokens": toks, "labels": toks}
    l0, _ = loss_fn(params, HYB_LORA, batch)
    params["groups"]["shared_lora"]["wq_b"] = (
        jnp.ones_like(params["groups"]["shared_lora"]["wq_b"]) * 0.02)
    l1, _ = loss_fn(params, HYB_LORA, batch)
    assert abs(float(l0) - float(l1)) > 1e-7


# ------------------------------------------------------------------- eval

def test_evaluate_matches_loss_fn():
    cfg = ModelConfig("t", "dense", 2, 64, 2, 2, 128, 128, head_dim=32,
                      dtype="float32", attn_impl="ref")
    params = init_params(jax.random.PRNGKey(0), cfg)
    pipe = TokenPipeline(DataConfig(vocab_size=128, seq_len=32,
                                    global_batch=4))
    res = evaluate(params, cfg, iter(pipe), n_batches=2)
    assert np.isfinite(res["eval_loss"])
    assert res["eval_ppl"] == pytest.approx(np.exp(res["eval_loss"]),
                                            rel=1e-5)
    assert res["eval_tokens"] == 2 * 4 * 31      # last label masked -100


# -------------------------------------------------------------- telemetry

def test_simulator_telemetry_export():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "run.jsonl")
        logger = MetricsLogger(path)
        wl = generate_workload(seed=3)[:8]
        master = DormMaster(paper_testbed(), "greedy",
                            OptimizerConfig(0.2, 0.2),
                            protocol=RecordingProtocol())
        res = ClusterSimulator(master, wl, horizon_s=12 * 3600,
                               logger=logger).run()
        assert len(logger.of_kind("sample")) == len(res.samples)
        timeline = logger.utilization_timeline()
        assert timeline and timeline[0][0] <= timeline[-1][0]
        summary = logger.summary()
        assert summary["events"] == len(res.samples)
        logger.close()
        rows = [json.loads(l) for l in open(path)]
        assert len(rows) == len(res.samples)


# ------------------------------------------------------ 2D elastic (subproc)

SUB_2D = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, json
    from repro.models.config import ModelConfig
    from repro.training.elastic import ElasticConfig, ElasticTrainer
    from repro.training.optimizer import OptimizerSpec
    from repro.data import DataConfig
    cfg = ElasticConfig(
        model=ModelConfig("t","dense",2,64,4,4,128,128,head_dim=16,
                          dtype="float32",attn_impl="ref"),
        optimizer=OptimizerSpec(peak_lr=1e-3, warmup_steps=2, total_steps=40),
        data=DataConfig(vocab_size=128, seq_len=32, global_batch=8),
        model_parallel=2)
    tr = ElasticTrainer(cfg, "tp2")
    tr.start(jax.devices()[:4])        # mesh (data=2, model=2)
    a = tr.train_steps(4)
    tr.resize(jax.devices()[:8])       # mesh (data=4, model=2), resharded
    b = tr.train_steps(4)
    print(json.dumps({"step": b["step"], "l0": a["loss"], "l1": b["loss"]}))
""")


@pytest.mark.slow
def test_elastic_2d_partition_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SUB_2D],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["step"] == 8
    assert np.isfinite(rec["l1"])
