"""Goodput curves, work anchoring and goodput-aware allocation (PR 9).

Covers: curve invariants (monotone, concave-capped, normalized), the
roofline-derived registry curves, linear bit-exactness (attaching the
explicit linear curve changes NOTHING vs no curve), the work-anchor
regression (replay anchors at the recorded request, synthetic traces at
the elasticity midpoint -- one shared definition), `speedup_ratios`'
explicit skip accounting, knee-capped greedy allocation, colgen's
goodput-weighted objective, numpy/jax parity of the goodput-aware greedy
path, and the master's cluster-goodput metric.
"""
import numpy as np
import pytest

from repro.core import (ApplicationSpec, ClusterSimulator, ClusterSpec,
                        DormMaster, GoodputCurve, OptimizerConfig,
                        RecordingProtocol, ReferenceClusterSimulator,
                        ResourceVector, SimResult, TraceConfig, WorkloadApp,
                        amdahl_curve, anchored_serial_work, backend_available,
                        curve_for_model, derive_curve, generate_trace,
                        heterogeneous_cluster, make_optimizer, paper_testbed,
                        speedup_ratios, work_anchor)
from repro.core.replay import ReplayConfig, _mk_app
from repro.configs.registry import ARCH_IDS


def app(i, cpus=2, gpus=0, ram=8, w=1, nmax=8, nmin=1, curve=None):
    return ApplicationSpec(f"app{i}", "MxNet",
                           ResourceVector.of(cpus, gpus, ram), w, nmax, nmin,
                           goodput=curve)


# --------------------------------------------------------------- invariants

def _assert_curve_invariants(c: GoodputCurve):
    tab = np.asarray(c.table)
    assert tab[0] == pytest.approx(1.0)
    marg = np.diff(tab, prepend=0.0)
    assert (marg >= -1e-12).all()                      # monotone
    assert (np.diff(marg) <= 1e-12).all()              # concave cap


def test_from_samples_enforces_invariants_on_noisy_data():
    c = GoodputCurve.from_samples([2.0, 3.9, 3.5, 8.0, 8.1])
    _assert_curve_invariants(c)
    # the N=4 spike (8.0/2.0 = 4x) must not beat concavity: marginal at 4
    # is capped by the (already capped) marginal at 3
    assert c.at(4) - c.at(3) <= c.at(3) - c.at(2) + 1e-12


def test_registry_curves_derive_and_hold_invariants():
    for arch in ARCH_IDS:
        _assert_curve_invariants(derive_curve(arch, 16))
    # MoE models saturate earlier than dense: total params drive the
    # all-reduce while only active params drive compute
    assert derive_curve("olmoe-1b-7b", 16).knee(16) < \
        derive_curve("gemma2-9b", 16).knee(16)


def test_amdahl_curve_saturates():
    c = amdahl_curve(64, alpha=0.1)
    _assert_curve_invariants(c)
    assert c.at(64) < 11.0            # 1/alpha = 10 asymptote


def test_extrapolation_past_table_is_linear_at_last_marginal():
    c = GoodputCurve.from_samples([1.0, 1.8, 2.4])
    last = c.at(3) - c.at(2)
    assert c.at(5) == pytest.approx(c.at(3) + 2 * last)
    assert c.eval(np.array([0, 1, 3, 5])).tolist() == \
        pytest.approx([0.0, 1.0, c.at(3), c.at(5)])


def test_knee_is_marginal_half_life():
    c = amdahl_curve(32, alpha=0.08)
    k = c.knee(32)
    assert 1 <= k <= 32
    assert c.at(k) - c.at(k - 1) >= 0.5 * c.at(1) - 1e-9
    if k < 32:
        assert c.at(k + 1) - c.at(k) < 0.5 * c.at(1)
    assert c.knee(4) <= 4             # n_max limits the knee
    assert GoodputCurve.linear(8).knee(8) == 8


# ------------------------------------------------------------ work anchoring

def test_work_anchor_definitions():
    assert work_anchor(1, 32, requested=20) == 20      # replay: the request
    assert work_anchor(4, 12) == 8                     # synthetic: midpoint
    assert work_anchor(1, 1) == 1
    assert anchored_serial_work(100.0, 8) == 100.0 * 8  # bit-exact, no curve
    c = amdahl_curve(8, 0.1)
    assert anchored_serial_work(100.0, 8, c) == pytest.approx(100.0 * c.at(8))


def test_replay_anchors_at_requested_count_regression():
    # Regression for the anchor inconsistency: replay previously used
    # duration * n_max while generate_trace used the midpoint with no
    # shared definition. Replay's recorded duration IS at the request.
    w = _mk_app("j1", "tf", ResourceVector.of(2, 0, 8), 1,
                n_min=2, n_max=10, duration_s=500.0, submit_time=0.0)
    assert w.spec.serial_work == 500.0 * 10
    # curved replay: work = duration * goodput(request), strictly less
    # than linear for a saturating curve
    wc = _mk_app("j1", "tf", ResourceVector.of(2, 0, 8), 1,
                 n_min=2, n_max=10, duration_s=500.0, submit_time=0.0,
                 cfg=ReplayConfig(goodput_curves=True))
    assert wc.spec.goodput is not None
    assert wc.spec.serial_work == pytest.approx(
        500.0 * wc.spec.goodput.at(10))
    assert wc.spec.serial_work < w.spec.serial_work


def test_trace_curves_attach_to_train_jobs_only():
    wl = generate_trace(TraceConfig(n_apps=40, seed=3, goodput_curves=True))
    curved = [w for w in wl if w.spec.goodput is not None]
    assert curved, "expected some curved train jobs"
    for w in curved:
        assert w.spec.model in ARCH_IDS
        assert w.spec.service_s == 0.0                 # train-class only
        _assert_curve_invariants(w.spec.goodput)
        anchor = work_anchor(w.spec.n_min, w.spec.n_max)
        assert w.spec.serial_work == pytest.approx(
            w.base_duration_s * w.spec.goodput.at(anchor))
    # default stays uncurved (bit-exact seed workload)
    assert all(w.spec.goodput is None
               for w in generate_trace(TraceConfig(n_apps=20, seed=3)))


# ------------------------------------------------------- linear bit-exactness

def _run(wl, horizon=24 * 3600.0, cfg=None, ref=False):
    m = DormMaster(paper_testbed(), "greedy",
                   cfg or OptimizerConfig(0.2, 0.2),
                   protocol=RecordingProtocol())
    sim_cls = ReferenceClusterSimulator if ref else ClusterSimulator
    return sim_cls(m, wl, adjustment_cost_s=60.0, horizon_s=horizon).run()


def _timeline(res: SimResult):
    return ([(s.t, s.utilization, s.fairness_loss, s.running, s.pending)
             for s in res.samples],
            {a: (rt.started_at, rt.finished_at)
             for a, rt in res.completions.items()})


def test_linear_curve_is_bit_exact_with_no_curve():
    wl = generate_trace(TraceConfig(n_apps=30, seed=7))
    wl_lin = [WorkloadApp(
        spec=__import__("dataclasses").replace(
            w.spec, goodput=GoodputCurve.linear(w.spec.n_max)),
        class_index=w.class_index, base_duration_s=w.base_duration_s,
        load=w.load) for w in wl]
    assert _timeline(_run(wl)) == _timeline(_run(wl_lin))


def test_runtime_matches_reference_on_curved_workload():
    wl = generate_trace(TraceConfig(n_apps=25, seed=11, goodput_curves=True,
                                    serving_fraction=0.0))
    assert _timeline(_run(wl)) == _timeline(_run(wl, ref=True))


def test_curved_jobs_progress_by_goodput_not_count():
    c = GoodputCurve.from_samples([1.0, 1.5, 1.75, 1.875])
    cluster = ClusterSpec.homogeneous(4, ResourceVector.of(8, 0, 32))
    spec = ApplicationSpec("a", "x", ResourceVector.of(2, 0, 8), 1, 4, 4,
                           serial_work=anchored_serial_work(1000.0, 4, c),
                           goodput=c)
    m = DormMaster(cluster, "greedy", OptimizerConfig(0.5, 0.5),
                   protocol=RecordingProtocol())
    res = ClusterSimulator(m, [WorkloadApp(spec=spec, class_index=0,
                                           base_duration_s=1000.0)],
                           adjustment_cost_s=0.0, horizon_s=1e6).run()
    rt = res.completions["a"]
    # pinned at N=4: finishes in exactly the anchored duration, NOT the
    # linear serial_work/4
    assert rt.finished_at - rt.started_at == pytest.approx(1000.0)


# ------------------------------------------------------------- speedup_ratios

def _result_with(durations, horizon=1000.0):
    runtimes = {}
    for a, (t0, t1) in durations.items():
        rt = AppRuntimeStub(t0, t1)
        runtimes[a] = rt
    return SimResult(samples=[], completions=runtimes,
                     total_adjustments=0, horizon_s=horizon)


class AppRuntimeStub:
    def __init__(self, t0, t1):
        self.submitted_at = t0
        self.started_at = t0
        self.finished_at = t1


def test_speedup_ratios_reports_skips_explicitly():
    dorm = _result_with({"a": (0.0, 10.0), "b": (0.0, 20.0)})
    base = _result_with({"a": (0.0, 30.0), "c": (0.0, 40.0)})
    skipped = {}
    sp = speedup_ratios(dorm, base, skipped=skipped)
    assert sp == {"a": pytest.approx(3.0)}
    assert skipped == {"b": "dorm-only", "c": "baseline-only"}


def test_speedup_ratios_raises_on_zero_duration_dorm_app():
    dorm = _result_with({"a": (5.0, 5.0)})
    base = _result_with({"a": (0.0, 30.0)})
    with pytest.raises(ValueError, match="non-positive dorm duration"):
        speedup_ratios(dorm, base)


# --------------------------------------------------- goodput-aware allocation

def test_greedy_caps_curved_app_at_knee():
    cluster = ClusterSpec.homogeneous(8, ResourceVector.of(8, 0, 32))
    c = curve_for_model("olmoe-1b-7b", 32)       # early knee (MoE)
    knee = c.knee(32)
    assert knee < 32
    opt_on = make_optimizer("greedy", OptimizerConfig(0.5, 0.5))
    opt_off = make_optimizer("greedy",
                             OptimizerConfig(0.5, 0.5, goodput_aware=False))
    apps = [app(1, nmax=32, curve=c)]
    on = opt_on.solve(apps, cluster, None)
    off = opt_off.solve(apps, cluster, None)
    assert int(off.x.sum()) == 32                # linear target: n_max
    assert int(on.x.sum()) == knee               # goodput target: the knee


def test_knee_capping_never_violates_n_min():
    cluster = ClusterSpec.homogeneous(8, ResourceVector.of(8, 0, 32))
    c = curve_for_model("olmoe-1b-7b", 32)
    apps = [app(1, nmax=32, nmin=max(c.knee(32) + 2, 2), curve=c)]
    alloc = make_optimizer("greedy", OptimizerConfig(0.5, 0.5)).solve(
        apps, cluster, None)
    assert int(alloc.x.sum()) >= apps[0].n_min


def test_colgen_objective_weights_columns_by_goodput():
    cluster = ClusterSpec.homogeneous(6, ResourceVector.of(8, 0, 32))
    moe = curve_for_model("olmoe-1b-7b", 24)
    apps = [app(1, nmax=24, curve=moe),          # saturates early
            app(2, nmax=24)]                     # linear
    opt = make_optimizer("colgen", OptimizerConfig(0.5, 0.5))
    alloc = opt.solve(apps, cluster, None)
    counts = {a: int(alloc.x[i].sum())
              for i, a in enumerate(alloc.app_ids)}
    # past the MoE knee a container buys ~0 goodput for app1 but 1.0 for
    # the linear app2: the goodput-weighted IP routes the contested
    # capacity (48 containers for 2x24 demand) to app2
    assert counts["app2"] > counts["app1"]
    assert counts["app1"] >= moe.knee(24) or counts["app1"] >= apps[0].n_min


@pytest.mark.skipif(not backend_available("jax"),
                    reason="jax backend not available")
def test_goodput_greedy_numpy_jax_parity():
    wl = generate_trace(TraceConfig(n_apps=12, seed=5, goodput_curves=True,
                                    serving_fraction=0.0))
    cluster = heterogeneous_cluster(32, seed=0)
    allocs = []
    for be in ("numpy", "jax"):
        opt = make_optimizer("greedy", OptimizerConfig(0.2, 0.2, backend=be))
        alloc = opt.solve([w.spec for w in wl], cluster, None)
        allocs.append((alloc.app_ids, alloc.x.tolist()))
    assert allocs[0] == allocs[1]


def test_master_reports_cluster_goodput():
    cluster = ClusterSpec.homogeneous(4, ResourceVector.of(8, 0, 32))
    c = curve_for_model("olmoe-1b-7b", 8)
    m = DormMaster(cluster, "greedy", OptimizerConfig(0.5, 0.5),
                   protocol=RecordingProtocol())
    res = m.submit(app(1, nmax=8, curve=c))
    n = m.containers_of("app1")
    assert res.goodput == pytest.approx(c.at(n))
    res2 = m.submit(app(2, nmax=8))              # uncurved: counts linearly
    total = res2.goodput
    assert total == pytest.approx(
        c.at(m.containers_of("app1")) + m.containers_of("app2"))
    res3 = m.complete("app1")
    assert res3.goodput == pytest.approx(float(m.containers_of("app2")))
    # uncurved masters keep the 0.0 default (metric fully gated)
    m2 = DormMaster(cluster, "greedy", OptimizerConfig(0.5, 0.5),
                    protocol=RecordingProtocol())
    assert m2.submit(app(3)).goodput == 0.0
