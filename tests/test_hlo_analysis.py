"""Trip-count-aware HLO accounting tests (the §Roofline measurement layer).

These pin the exact behaviors EXPERIMENTS.md §Perf M.1/M.2 rely on:
  * cost_analysis counts scan bodies once (the bug we correct),
  * analyze_hlo matches the true FLOPs for scan / unrolled / nested scans,
  * f32 collective tracking and the TPU dtype correction.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import HloTotals, analyze_hlo

D = 256


def _body(x, w):
    return jnp.tanh(x @ w), None


def _scanned(x, ws):
    y, _ = jax.lax.scan(_body, x, ws)
    return y


def _unrolled(x, ws):
    for i in range(8):
        x = jnp.tanh(x @ ws[i])
    return x


def _nested(x, ws):
    def outer(x, wg):
        y, _ = jax.lax.scan(_body, x, wg)
        return y, None
    y, _ = jax.lax.scan(outer, x, ws)
    return y


X = jax.ShapeDtypeStruct((128, D), jnp.float32)
WS = jax.ShapeDtypeStruct((8, D, D), jnp.float32)
WS_NEST = jax.ShapeDtypeStruct((4, 3, D, D), jnp.float32)
PER_LAYER = 2 * 128 * D * D


def test_cost_analysis_undercounts_scan_bodies():
    """Documents the XLA behavior we correct (if XLA ever fixes it, this
    test will flag that the correction should be revisited)."""
    c = jax.jit(_scanned).lower(X, WS).compile().cost_analysis()
    c = c[0] if isinstance(c, (list, tuple)) else c
    assert float(c["flops"]) <= PER_LAYER * 1.5      # ~1 body, not 8


@pytest.mark.parametrize("fn,ws,layers", [
    (_scanned, WS, 8), (_unrolled, WS, 8), (_nested, WS_NEST, 12)])
def test_analyze_hlo_exact_flops(fn, ws, layers):
    hlo = jax.jit(fn).lower(X, ws).compile().as_text()
    tot = analyze_hlo(hlo)
    assert tot.dot_flops == pytest.approx(layers * PER_LAYER, rel=1e-6)


def test_tpu_dtype_correction():
    t = HloTotals(
        dot_flops=0.0,
        collective_bytes={"all-reduce": 100.0, "all-gather": 50.0},
        collective_bytes_f32={"all-reduce": 80.0, "all-gather": 0.0})
    # bf16 model: f32 ARs halve (CPU upcast artifact), rest unchanged
    assert t.tpu_corrected_bytes(True) == pytest.approx(20 + 40 + 50)
    assert t.tpu_corrected_bytes(False) == pytest.approx(150.0)


def test_collective_weight_model():
    """all-reduce rings move 2x the buffer (reduce + broadcast phases)."""
    from repro.launch.hlo_analysis import _WEIGHT
    assert _WEIGHT["all-reduce"] == 2.0
    assert _WEIGHT["all-gather"] == 1.0
