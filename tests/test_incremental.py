"""The incremental scheduling fast path is a PURE optimization: incremental
DRF refill (saturating fast path) and delta-aware reallocation must produce
allocations bit-exact with the full re-solve, on individual instances and
across whole event streams from the trace generator."""
import numpy as np
import pytest

from repro.core import (ClusterSimulator, ClusterSpec, DormMaster,
                        GreedyOptimizer, OptimizerConfig, Reallocated,
                        RecordingProtocol, ResourceVector, TraceConfig,
                        drf_container_counts, generate_trace,
                        heterogeneous_cluster, saturating_counts)


def _masters(cluster, theta=(0.2, 0.2)):
    return (
        DormMaster(cluster, "greedy",
                   OptimizerConfig(*theta, incremental=True),
                   protocol=RecordingProtocol()),
        DormMaster(cluster, "greedy",
                   OptimizerConfig(*theta, incremental=False),
                   protocol=RecordingProtocol()),
    )


def _run_recording(master, wl, horizon_s=24 * 3600.0):
    """Simulate and record every event's full allocation matrix."""
    allocs = []
    sim = ClusterSimulator(master, wl, horizon_s=horizon_s)
    sim.runtime.bus.subscribe(
        Reallocated,
        lambda e: allocs.append((e.t, e.result.allocation.app_ids,
                                 e.result.allocation.x.copy())))
    res = sim.run()
    return res, allocs


def _assert_stream_bit_exact(cluster, wl):
    m_inc, m_full = _masters(cluster)
    res_i, al_i = _run_recording(m_inc, wl)
    res_f, al_f = _run_recording(m_full, wl)
    assert len(al_i) == len(al_f)
    for (ti, ids_i, x_i), (tf, ids_f, x_f) in zip(al_i, al_f):
        assert ti == tf
        assert ids_i == ids_f
        np.testing.assert_array_equal(x_i, x_f)
    assert len(res_i.samples) == len(res_f.samples)
    for a, b in zip(res_i.samples, res_f.samples):
        assert a == b
    assert res_i.durations() == res_f.durations()
    return m_inc


def test_incremental_bit_exact_abundant_cluster():
    """Abundant capacity: the delta path answers most events."""
    cluster = heterogeneous_cluster(60, seed=1)
    wl = generate_trace(TraceConfig(n_apps=60, seed=4,
                                    mean_interarrival_s=600.0))
    m = _assert_stream_bit_exact(cluster, wl)
    assert m.optimizer.delta_solves > 0        # the fast path actually ran


def test_incremental_bit_exact_saturated_cluster():
    """Tight capacity: the fast path must bail out to the full solve and
    still match (including infeasible/pending episodes)."""
    cluster = heterogeneous_cluster(10, seed=2)
    wl = generate_trace(TraceConfig(n_apps=40, seed=7,
                                    mean_interarrival_s=120.0))
    m = _assert_stream_bit_exact(cluster, wl)
    assert m.optimizer.drf.full_refills > 0    # fallback actually exercised


def test_saturating_counts_matches_full_filling_when_it_answers():
    rng = np.random.default_rng(0)
    for trial in range(50):
        b = int(rng.integers(1, 6))
        cluster = ClusterSpec.homogeneous(
            b, ResourceVector.of(int(rng.integers(8, 64)),
                                 int(rng.integers(0, 3)),
                                 int(rng.integers(32, 128))))
        apps = []
        for i in range(int(rng.integers(1, 6))):
            n_min = int(rng.integers(1, 3))
            from repro.core import ApplicationSpec
            apps.append(ApplicationSpec(
                f"a{i}", "x",
                ResourceVector.of(int(rng.integers(1, 4)),
                                  int(rng.integers(0, 2)),
                                  int(rng.integers(1, 16))),
                int(rng.integers(1, 4)), n_min + int(rng.integers(0, 8)),
                n_min))
        fast = saturating_counts(apps, cluster)
        if fast is not None:
            assert fast == drf_container_counts(apps, cluster)


def test_greedy_delta_and_full_agree_on_solve_sequence():
    """Direct optimizer-level check: replay a submit stream through two
    GreedyOptimizers (delta on/off), feeding each its own prev allocation."""
    cluster = heterogeneous_cluster(30, seed=3)
    wl = generate_trace(TraceConfig(n_apps=25, seed=9,
                                    mean_interarrival_s=300.0))
    inc = GreedyOptimizer(OptimizerConfig(0.2, 0.2, incremental=True))
    full = GreedyOptimizer(OptimizerConfig(0.2, 0.2, incremental=False))
    apps = []
    prev_i = prev_f = None
    for w in wl:
        apps.append(w.spec)
        a_i = inc.solve(apps, cluster, prev_i)
        a_f = full.solve(apps, cluster, prev_f)
        assert (a_i is None) == (a_f is None)
        if a_i is not None:
            assert a_i.app_ids == a_f.app_ids
            np.testing.assert_array_equal(a_i.x, a_f.x)
            assert inc.last_shares == pytest.approx(full.last_shares)
            prev_i, prev_f = a_i, a_f
    assert inc.delta_solves > 0


def test_fractional_demands_take_delta_path_and_stay_bit_exact():
    """Non-integer demands (Philly n_cpus/n_gpus, Alibaba plan_cpu/100
    replays). PR 6 closed the replay delta-solve hole: the SoA engine now
    canonicalizes the free matrix (one  cap - x^T d  matmul on both the
    delta and full paths), so fractional streams take the incremental path
    AND stay bit-exact with the full re-solve."""
    from repro.core import ApplicationSpec, WorkloadApp
    cluster = ClusterSpec.homogeneous(6, ResourceVector.of(10, 0, 64))
    wl = []
    for i in range(8):
        spec = ApplicationSpec(
            f"f{i}", "x", ResourceVector.of(0.57, 0, 3.3), 1, 4, 1,
            serial_work=3600.0 * 4, submit_time=600.0 * i)
        wl.append(WorkloadApp(spec=spec, class_index=0,
                              base_duration_s=3600.0))
    m_inc = _assert_stream_bit_exact(cluster, wl)
    assert m_inc.optimizer.delta_solves > 0      # the hole is closed
    # The legacy engine keeps the old conservative guard (its full path
    # subtracts rows sequentially, so the matmul warm start must decline).
    m_leg = DormMaster(cluster, "greedy",
                       OptimizerConfig(0.2, 0.2, incremental=True,
                                       soa=False),
                       protocol=RecordingProtocol())
    _run_recording(m_leg, wl)
    assert m_leg.optimizer.delta_solves == 0


# ------------------------------------------------- hypothesis stream check

def test_incremental_bit_exact_property():
    """Property: for random generator traces on random cluster sizes, the
    incremental and full re-solve masters produce identical allocation
    streams (the headline guarantee of the incremental path)."""
    pytest.importorskip(
        "hypothesis",
        reason="hypothesis is not in the baked image (no pip install "
               "allowed); this property test runs wherever it is available")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(0, 10 ** 6), st.integers(12, 80),
           st.sampled_from([240.0, 900.0]))
    @settings(max_examples=8, deadline=None)
    def check(seed, n_slaves, inter):
        cluster = heterogeneous_cluster(n_slaves, seed=seed % 17)
        wl = generate_trace(TraceConfig(n_apps=30, seed=seed,
                                        mean_interarrival_s=inter))
        _assert_stream_bit_exact(cluster, wl)

    check()


# ------------------------------------------ shrink-resize guard (directed)
# PR 2 added the guard (a shrunk bound can push a target below the previous
# count, so the prev-rows warm start must decline); these exercise it
# directly instead of hoping a generator trace hits it.

def _mk(i, nmax=8, nmin=1, cpus=2, ram=8, work=200 * 3600.0, t=0.0):
    from repro.core import ApplicationSpec, WorkloadApp
    spec = ApplicationSpec(f"s{i}", "x", ResourceVector.of(cpus, 0, ram),
                           1, nmax, nmin, serial_work=work, submit_time=t)
    return WorkloadApp(spec=spec, class_index=0, base_duration_s=work)


def test_shrink_below_current_count_declines_delta_and_trims():
    """Abundant cluster, app sitting at n_max via the delta fast path; a
    Resize shrinking n_max below the current count must route through the
    FULL solve (the warm start would keep an illegal row) and trim."""
    cluster = ClusterSpec.homogeneous(4, ResourceVector.of(8, 0, 32))
    m_inc, m_full = _masters(cluster, theta=(1.0, 1.0))
    for m in (m_inc, m_full):
        m.on_arrival((_mk(0).spec,))
        m.on_arrival((_mk(1).spec,))
    assert m_inc.containers_of("s0") == 8          # fast path grew to n_max
    delta_before = m_inc.optimizer.delta_solves
    full_before = m_inc.optimizer.full_solves
    res_i = m_inc.on_resize("s0", None, 3)
    res_f = m_full.on_resize("s0", None, 3)
    assert m_inc.optimizer.full_solves == full_before + 1   # guard fired
    assert m_inc.optimizer.delta_solves == delta_before
    assert m_inc.containers_of("s0") == 3
    assert res_i.allocation.app_ids == res_f.allocation.app_ids
    np.testing.assert_array_equal(res_i.allocation.x, res_f.allocation.x)
    # the trim is an adjustment (save -> kill -> resume)
    assert "s0" in res_i.adjusted_app_ids


def test_shrink_then_grow_in_one_tick_window_bit_exact():
    """Two injected resizes at the SAME timestamp (shrink, then grow back):
    both must apply in injection order, and the incremental master's
    timeline must match the full re-solve master's bit-for-bit."""
    from repro.core import ClusterRuntime, Reallocated, Resize
    cluster = ClusterSpec.homogeneous(4, ResourceVector.of(8, 0, 32))
    wl = [_mk(0), _mk(1)]

    def drive(master):
        rt = ClusterRuntime(master, horizon_s=12 * 3600.0)
        rt.inject(Resize(3600.0, "s0", n_max=2),
                  Resize(3600.0, "s0", 4, 6))
        allocs = []
        rt.bus.subscribe(Reallocated,
                         lambda e: allocs.append(
                             (e.t, e.result.allocation.app_ids,
                              e.result.allocation.x.copy())))
        res = rt.run(wl)
        return res, allocs

    m_inc, m_full = _masters(cluster, theta=(1.0, 1.0))
    res_i, al_i = drive(m_inc)
    res_f, al_f = drive(m_full)
    assert m_inc.specs["s0"].n_max == 6            # the grow won (last)
    assert 4 <= m_inc.containers_of("s0") <= 6
    assert len(al_i) == len(al_f)
    for (ti, ids_i, x_i), (tf, ids_f, x_f) in zip(al_i, al_f):
        assert ti == tf and ids_i == ids_f
        np.testing.assert_array_equal(x_i, x_f)
    assert res_i.durations() == res_f.durations()


def test_shrink_during_futile_topup_memo_hit():
    """ClusterState.epoch interaction: a futile top-up memo entry must not
    survive a Resize (update_spec/rebound bumps the epoch), or the freed
    capacity of the shrunk app could never reach the memoized app.

    Setup: 2 slaves x 8 cpus, 3-cpu containers. s0 takes 3 (2+1), s1 gets
    1 and records a futile top-up to 2 (free is 2 cpus per slave). Then s0
    shrinks to n_max=2: one container's capacity returns, and s1's next
    solve MUST claim it -- which only happens if the memo was invalidated."""
    cluster = ClusterSpec.homogeneous(2, ResourceVector.of(8, 0, 32))
    m_inc, m_full = _masters(cluster, theta=(1.0, 1.0))
    a0 = _mk(0, nmax=3, cpus=3, ram=1).spec
    a1 = _mk(1, nmax=2, cpus=3, ram=1).spec
    for m in (m_inc, m_full):
        m.on_arrival((a0,))
        m.on_arrival((a1,))
    assert m_inc.containers_of("s0") == 3
    assert m_inc.containers_of("s1") == 1          # top-up to 2 was futile
    memo = m_inc.optimizer._futile
    assert memo.get("s1") is not None              # the memo actually hit
    epoch_before = m_inc.state.epoch
    res_i = m_inc.on_resize("s0", None, 2)
    res_f = m_full.on_resize("s0", None, 2)
    assert m_inc.state.epoch > epoch_before        # rebound bumped epoch
    assert m_inc.containers_of("s0") == 2
    assert m_inc.containers_of("s1") == 2          # freed slot claimed
    np.testing.assert_array_equal(res_i.allocation.x, res_f.allocation.x)
    assert res_i.allocation.app_ids == res_f.allocation.app_ids


def test_master_reports_eq4_adjustment_overhead():
    """Satellite: ReallocationResult.adjustment_overhead is the literal Eq-4
    count vs prev_alloc (== the number of adjusted running apps)."""
    cluster = ClusterSpec.homogeneous(2, ResourceVector.of(8, 0, 32))
    m = DormMaster(cluster, "greedy", OptimizerConfig(1.0, 1.0),
                   protocol=RecordingProtocol())
    from repro.core import ApplicationSpec
    m.submit(ApplicationSpec("a", "x", ResourceVector.of(2, 0, 8), 1, 8, 1))
    res = m.submit(ApplicationSpec("b", "x", ResourceVector.of(2, 0, 8),
                                   1, 8, 1))
    assert res.adjustment_overhead == len(res.adjusted_app_ids)
    res2 = m.complete("b")
    assert res2.adjustment_overhead == len(res2.adjusted_app_ids)
