"""Pallas-kernel tests: shape/dtype sweeps against the pure-jnp oracles,
executed in interpret mode on CPU (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention_gqa
from repro.kernels.moe_gemm import moe_gemm
from repro.kernels.rmsnorm import rmsnorm as rmsnorm_kernel
from repro.kernels.ssd_scan import ssd_scan as ssd_kernel


def _tol(dtype):
    return 6e-2 if dtype == jnp.bfloat16 else 3e-5


# ------------------------------------------------------------------ flash

FLASH_CASES = [
    # B, Hkv, G, S, Dh, causal, window, softcap
    (1, 2, 2, 256, 64, True, None, 0.0),
    (2, 1, 4, 256, 128, True, 64, 0.0),
    (1, 2, 1, 512, 64, True, None, 50.0),
    (1, 1, 2, 256, 64, False, None, 0.0),
    (1, 1, 1, 384, 64, True, 200, 30.0),
    (2, 2, 2, 128, 32, True, None, 0.0),
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_oracle(case, dtype):
    B, Hkv, G, S, Dh, causal, window, cap = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, Hkv, G, S, Dh), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, Dh), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, Dh), dtype)
    o = flash_attention_gqa(q, k, v, causal=causal, window=window,
                            logit_softcap=cap, block_q=128, block_k=128,
                            interpret=True)
    oref = kref.attention_ref(q.reshape(B, Hkv * G, S, Dh), k, v,
                              causal=causal, window=window,
                              logit_softcap=cap).reshape(q.shape)
    err = float(jnp.abs(o.astype(jnp.float32)
                        - oref.astype(jnp.float32)).max())
    assert err < _tol(dtype), err


def test_flash_block_shape_sweep():
    B, Hkv, G, S, Dh = 1, 1, 2, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, Hkv, G, S, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, S, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, S, Dh))
    oref = kref.attention_ref(q.reshape(B, Hkv * G, S, Dh), k, v,
                              causal=True).reshape(q.shape)
    for bq, bk in [(64, 64), (128, 256), (256, 128), (512, 512)]:
        o = flash_attention_gqa(q, k, v, causal=True, block_q=bq,
                                block_k=bk, interpret=True)
        assert float(jnp.abs(o - oref).max()) < 3e-5, (bq, bk)


# -------------------------------------------------------------------- ssd

SSD_CASES = [
    # B, H, C, L, P, N
    (2, 3, 4, 32, 16, 8),
    (1, 2, 8, 64, 32, 16),
    (1, 1, 4, 128, 64, 128),
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_vs_oracle(case, dtype):
    B, H, C, L, P, N = case
    ks = jax.random.split(jax.random.PRNGKey(sum(case)), 5)
    xh = jax.random.normal(ks[0], (B, H, C, L, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, C, L))
                         ).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, C, L, N), dtype)
    Cm = jax.random.normal(ks[4], (B, C, L, N), dtype)
    y, h = ssd_kernel(xh, dt, A, Bm, Cm, interpret=True)
    S = C * L
    yr, hr = kref.ssd_ref(
        jnp.moveaxis(xh.reshape(B, H, S, P), 1, 2).astype(jnp.float32),
        jnp.moveaxis(dt.reshape(B, H, S), 1, 2), A,
        Bm.reshape(B, S, N).astype(jnp.float32),
        Cm.reshape(B, S, N).astype(jnp.float32))
    yr = jnp.moveaxis(yr, 2, 1).reshape(B, H, C, L, P)
    scale = max(1.0, float(jnp.abs(yr).max()))
    assert float(jnp.abs(y.astype(jnp.float32) - yr).max()) / scale \
        < (2e-2 if dtype == jnp.bfloat16 else 1e-4)
    assert float(jnp.abs(h - hr).max()) < 1e-2


# --------------------------------------------------------------- moe gemm

@pytest.mark.parametrize("shape", [(4, 128, 128, 256), (2, 256, 128, 128),
                                   (8, 128, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gemm_vs_oracle(shape, dtype):
    E, C, D, F = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(sum(shape)))
    x = jax.random.normal(k1, (E, C, D), dtype)
    w = jax.random.normal(k2, (E, D, F), dtype)
    y = moe_gemm(x, w, interpret=True)
    yr = kref.moe_gemm_ref(x, w)
    scale = max(1.0, float(jnp.abs(yr.astype(jnp.float32)).max()))
    err = float(jnp.abs(y.astype(jnp.float32)
                        - yr.astype(jnp.float32)).max()) / scale
    assert err < (3e-2 if dtype == jnp.bfloat16 else 1e-5), err


# ---------------------------------------------------------------- rmsnorm

@pytest.mark.parametrize("shape", [(256, 128), (512, 512), (64, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_vs_oracle(shape, dtype):
    R, D = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(R + D))
    x = jax.random.normal(k1, (R, D), dtype)
    w = (jax.random.normal(k2, (D,)) * 0.1).astype(dtype)
    y = rmsnorm_kernel(x, w, block_rows=min(256, R), interpret=True)
    yr = kref.rmsnorm_ref(x, w)
    err = float(jnp.abs(y.astype(jnp.float32)
                        - yr.astype(jnp.float32)).max())
    assert err < _tol(dtype), err


# ------------------------------------------------------- ops.py dispatch

def test_ops_auto_falls_back_to_ref_on_cpu():
    # on the CPU test container, impl="auto" must use the jnp oracle path
    assert jax.default_backend() == "cpu"
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    o_auto = ops.flash_attention(q, k, v, impl="auto")
    o_ref = ops.flash_attention(q, k, v, impl="ref")
    assert float(jnp.abs(o_auto - o_ref).max()) == 0.0


# -------------------------------------------------- placement (scheduler)

PLACE_SIZES = [8, 32, 256, 512]


@pytest.mark.parametrize("b", PLACE_SIZES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_best_fit_counts_vs_oracle(b, dtype):
    from jax.experimental import enable_x64

    from repro.kernels.placement import best_fit_counts, best_fit_counts_ref
    with enable_x64():
        rng = np.random.default_rng(b)
        for trial in range(6):
            score = rng.uniform(0.0, 4.0, size=b)
            if trial % 2:                      # force ties + infeasibles
                score = np.round(score, 1)
                score[rng.integers(b, size=max(b // 4, 1))] = np.inf
            q = rng.integers(0, 7, size=b).astype(np.int32)
            q[~np.isfinite(score)] = 0         # contract: infeasible q=0
            need = np.int32(rng.integers(1, int(q.sum()) + 2))
            q = np.minimum(q, need).astype(np.int32)
            s = jnp.asarray(score, dtype=dtype)
            got = best_fit_counts(s, jnp.asarray(q), jnp.asarray(need),
                                  block=256, interpret=True)
            ref = best_fit_counts_ref(s, jnp.asarray(q), jnp.asarray(need))
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref),
                                          err_msg=f"b={b} trial={trial}")


def test_best_fit_counts_rejects_ragged_block():
    from repro.kernels.placement import best_fit_counts
    with pytest.raises(ValueError):
        best_fit_counts(jnp.zeros(10), jnp.zeros(10, jnp.int32),
                        jnp.int32(1), block=4, interpret=True)
