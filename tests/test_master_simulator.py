"""DormMaster lifecycle + cluster-simulator behaviour tests."""
import numpy as np
import pytest

from repro.core import (ApplicationSpec, ClusterSimulator, ClusterSpec,
                        DormMaster, OptimizerConfig, RecordingProtocol,
                        ResourceVector, StaticScheduler, TaskLevelOverheadModel,
                        generate_workload, paper_testbed, speedup_ratios,
                        BASELINE_STATIC_CONTAINERS, sample_task_duration_s)


def mk_master(kind="greedy", theta=(0.2, 0.2)):
    return DormMaster(paper_testbed(), kind, OptimizerConfig(*theta),
                      protocol=RecordingProtocol())


def app(i, cpus=2, gpus=0, ram=8, w=1, nmax=8, nmin=1):
    return ApplicationSpec(f"app{i}", "MxNet",
                           ResourceVector.of(cpus, gpus, ram), w, nmax, nmin)


def test_submit_places_app_and_deploys_executors():
    m = mk_master()
    res = m.submit(app(1))
    assert m.containers_of("app1") >= 1
    assert "app1" in res.started_app_ids
    n = m.containers_of("app1")
    # one TaskExecutor + TaskScheduler per container (§III-A.3)
    assert len(m.executors["app1"]) == n
    assert len(m.schedulers["app1"]) == n
    # TaskScheduler places tasks locally only (§III-D)
    placements = m.schedulers["app1"][0].place(4)
    assert all(c == m.schedulers["app1"][0].container_id
               for c, _ in placements)


def test_complete_releases_resources():
    m = mk_master()
    m.submit(app(1))
    used_before = sum(s.used().sum() for s in m.slaves.values())
    assert used_before > 0
    m.complete("app1")
    assert sum(s.used().sum() for s in m.slaves.values()) == 0


def test_adjustment_protocol_sequence():
    m = mk_master()
    m.submit(app(1, nmax=32))
    proto = m.protocol
    m.submit(app(2, nmax=32))           # forces a resize of app1
    kinds = [e.kind for e in proto.events if e.app_id == "app1"]
    if "resume" in kinds:               # app1 was adjusted
        i_save = kinds.index("save")
        i_kill = kinds.index("kill")
        i_resume = kinds.index("resume")
        assert i_save < i_kill < i_resume


def test_infeasible_keeps_pending():
    cluster = ClusterSpec.homogeneous(1, ResourceVector.of(4, 0, 16))
    m = DormMaster(cluster, "greedy", OptimizerConfig(0.1, 0.1),
                   protocol=RecordingProtocol())
    m.submit(ApplicationSpec("a", "x", ResourceVector.of(4, 0, 16), 1, 1, 1))
    res = m.submit(ApplicationSpec("b", "x", ResourceVector.of(4, 0, 16),
                                   1, 1, 1))
    # no room for b's n_min until a completes
    assert "b" in res.pending_app_ids
    res2 = m.complete("a")
    assert m.containers_of("b") == 1


def test_simulator_dorm_beats_static():
    wl = generate_workload(seed=1)[:20]
    cluster = paper_testbed()
    dorm = ClusterSimulator(
        DormMaster(cluster, "greedy", OptimizerConfig(0.2, 0.2),
                   protocol=RecordingProtocol()),
        wl, adjustment_cost_s=60.0, horizon_s=24 * 3600).run()
    static = {w.spec.app_id: BASELINE_STATIC_CONTAINERS[w.class_index]
              for w in wl}
    base = ClusterSimulator(
        StaticScheduler(cluster, static), wl,
        horizon_s=24 * 3600).run()
    u_d = dorm.time_averaged_utilization(5 * 3600)
    u_b = base.time_averaged_utilization(5 * 3600)
    assert u_d > u_b                    # Fig 6's qualitative claim
    sp = speedup_ratios(dorm, base)
    if sp:
        assert np.mean(list(sp.values())) > 1.0    # Fig 9a qualitative


def test_task_level_overhead_model_matches_paper_analysis():
    """§II-C: 430 ms latency on ~1.5 s tasks is significant overhead."""
    rng = np.random.default_rng(0)
    tasks = sample_task_duration_s(rng, 20_000)
    assert 0.4 < np.median(tasks) / 1.5 < 2.5      # Fig 1(b) calibration
    ov = TaskLevelOverheadModel().sharing_overhead(tasks)
    assert ov > 0.10                               # >10% overhead
