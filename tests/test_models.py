"""Model-substrate correctness: chunked attention vs reference, SSD chunked
vs naive recurrence, prefill/decode cache consistency, MoE invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn, prefill)
from repro.models.config import ModelConfig
from repro.models.layers import attention_chunked, attention_ref
from repro.models.moe import expert_capacity, init_moe, moe_block
from repro.models.ssm import ssd_chunked_ref


# ----------------------------------------------------- attention equivalence

@pytest.mark.parametrize("case", [
    dict(B=2, S=256, Hq=4, Hkv=2, Dh=64, causal=True, window=None, cap=0.0),
    dict(B=1, S=128, Hq=8, Hkv=8, Dh=32, causal=True, window=50, cap=50.0),
    dict(B=2, S=200, Hq=4, Hkv=1, Dh=64, causal=True, window=None, cap=0.0),
    dict(B=2, S=256, Hq=4, Hkv=4, Dh=64, causal=False, window=None, cap=0.0),
])
def test_chunked_attention_matches_ref(case):
    ks = jax.random.split(jax.random.PRNGKey(case["S"]), 3)
    q = jax.random.normal(ks[0], (case["B"], case["S"], case["Hq"], case["Dh"]))
    k = jax.random.normal(ks[1], (case["B"], case["S"], case["Hkv"], case["Dh"]))
    v = jax.random.normal(ks[2], (case["B"], case["S"], case["Hkv"], case["Dh"]))
    o1 = attention_ref(q, k, v, causal=case["causal"], window=case["window"],
                       logit_softcap=case["cap"])
    o2 = attention_chunked(q, k, v, causal=case["causal"],
                           window=case["window"], logit_softcap=case["cap"],
                           chunk=64)
    assert float(jnp.abs(o1 - o2).max()) < 1e-4


# ------------------------------------------------------------ ssd chunking

def test_ssd_chunked_matches_naive():
    B, S, H, P, N = 2, 64, 3, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))

    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A)
        h = dA[:, :, None, None] * h + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], xh[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t], h))
    y_naive = jnp.stack(ys, 1)

    for chunk in (8, 16, 64):
        y_c, h_c = ssd_chunked_ref(xh, dt, A, Bm, Cm, chunk)
        assert float(jnp.abs(y_naive - y_c).max()) < 1e-3, chunk
        assert float(jnp.abs(h - h_c).max()) < 1e-3, chunk


# --------------------------------------------- prefill == stepwise decode

CONFIGS = {
    "dense": ModelConfig("d", "dense", 2, 128, 4, 2, 256, 256, head_dim=32,
                         dtype="float32", attn_impl="ref"),
    "sliding": ModelConfig("s", "dense", 2, 128, 4, 4, 256, 256, head_dim=32,
                           dtype="float32", layer_pattern="sliding",
                           sliding_window=8, attn_impl="ref"),
    "local_global": ModelConfig(
        "lg", "dense", 4, 128, 4, 2, 256, 256, head_dim=32, dtype="float32",
        layer_pattern="local_global", sliding_window=8,
        attn_logit_softcap=50.0, use_post_norms=True, scale_embeddings=True,
        attn_impl="ref"),
    "ssm": ModelConfig("m", "ssm", 2, 128, 0, 0, 0, 256, dtype="float32",
                       ssm_state=16, ssm_head_dim=16, ssm_chunk=8),
    "hybrid": ModelConfig("h", "hybrid", 4, 128, 4, 4, 256, 256, head_dim=32,
                          dtype="float32", ssm_state=16, ssm_head_dim=16,
                          ssm_chunk=8, hybrid_attn_every=2, attn_impl="ref"),
    # capacity_factor=8: prefill (N=B*S) and decode (N=B) use different
    # per-call capacities, so token DROPPING differs between the two paths;
    # unbounded capacity isolates the cache-consistency property under test
    # (dropping semantics are covered in test_moe_capacity_dropping_and_aux).
    "moe": ModelConfig("e", "moe", 2, 128, 4, 4, 64, 256, head_dim=32,
                       dtype="float32", num_experts=4, num_experts_per_tok=2,
                       capacity_factor=8.0, attn_impl="ref"),
}


@pytest.mark.parametrize("family", sorted(CONFIGS))
def test_prefill_matches_stepwise_decode(family):
    cfg = CONFIGS[family]
    B, S = 2, 24
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    _, cache = prefill(params, cfg, toks[:, :S], S + 1)
    lgA, _ = decode_step(params, cfg, toks[:, S:S + 1], cache)

    cache2 = init_cache(cfg, B, S + 1)
    for t in range(S + 1):
        lgB, cache2 = decode_step(params, cfg, toks[:, t:t + 1], cache2)
    assert float(jnp.abs(lgA - lgB).max()) < 2e-3


# ------------------------------------------------------------- moe details

def test_moe_capacity_dropping_and_aux():
    cfg = CONFIGS["moe"]
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 128))
    out, aux = moe_block(p, x, cfg)
    assert out.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3     # aux >= 1 at/near balance by design
    C = expert_capacity(64, cfg)
    assert C % 8 == 0 and C >= 8


def test_moe_aux_detects_imbalance():
    cfg = CONFIGS["moe"]
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    # craft inputs with a constant component and a router that maps it to
    # expert 0 -> all tokens route there and aux must exceed the balanced one
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 128)) * 0.1
    x = x.at[..., 0].set(5.0)
    p_bad = dict(p)
    p_bad["router"] = jnp.zeros_like(p["router"]).at[0, 0].set(3.0)
    _, aux_bal = moe_block(p, x, cfg)
    _, aux_bad = moe_block(p_bad, x, cfg)
    assert float(aux_bad) > float(aux_bal) + 0.3


# ----------------------------------------------------------- loss masking

def test_loss_ignores_masked_labels():
    cfg = CONFIGS["dense"]
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    full, _ = loss_fn(params, cfg, {"tokens": toks, "labels": toks})
    labels_masked = toks.at[:, 8:].set(-100)
    half, _ = loss_fn(params, cfg,
                      {"tokens": toks, "labels": labels_masked})
    assert np.isfinite(float(half))
    assert abs(float(full) - float(half)) > 1e-6   # actually different set
