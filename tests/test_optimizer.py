"""Utilization-fairness optimizer (P2) tests: MILP exact vs greedy heuristic,
budget constraints Eq 15/16, infeasibility fallback."""
import numpy as np
import pytest

from repro.core import (Allocation, ApplicationSpec, ClusterSpec,
                        GreedyOptimizer, MilpOptimizer, OptimizerConfig,
                        ResourceVector, adjust_budget, cluster_fairness_loss,
                        fairness_budget, resource_adjustment_overhead,
                        resource_utilization, validate_allocation)


def small_cluster(b=4):
    return ClusterSpec.homogeneous(b, ResourceVector.of(8, 1, 32))


def apps3():
    return [
        ApplicationSpec("a1", "MxNet", ResourceVector.of(2, 0, 8), 1, 8, 1),
        ApplicationSpec("a2", "TF", ResourceVector.of(2, 0, 6), 2, 8, 1),
        ApplicationSpec("a3", "Caffe", ResourceVector.of(1, 1, 8), 1, 4, 1),
    ]


@pytest.mark.parametrize("kind", ["milp", "greedy"])
def test_solution_feasible(kind):
    cluster, apps = small_cluster(), apps3()
    opt = (MilpOptimizer if kind == "milp" else GreedyOptimizer)(
        OptimizerConfig(0.2, 0.2))
    alloc = opt.solve(apps, cluster, None)
    assert alloc is not None
    validate_allocation(alloc, apps, cluster)


def test_milp_beats_or_matches_greedy_utilization():
    cluster, apps = small_cluster(), apps3()
    cfg = OptimizerConfig(0.2, 0.2)
    a_m = MilpOptimizer(cfg).solve(apps, cluster, None)
    a_g = GreedyOptimizer(cfg).solve(apps, cluster, None)
    u_m = resource_utilization(a_m, apps, cluster)
    u_g = resource_utilization(a_g, apps, cluster)
    assert u_m >= u_g - 1e-9


@pytest.mark.parametrize("kind", ["milp", "greedy"])
@pytest.mark.parametrize("theta1", [0.05, 0.1, 0.3])
def test_fairness_budget_respected(kind, theta1):
    cluster, apps = small_cluster(), apps3()
    cfg = OptimizerConfig(theta1, 1.0)
    opt = (MilpOptimizer if kind == "milp" else GreedyOptimizer)(cfg)
    alloc = opt.solve(apps, cluster, None)
    assert alloc is not None
    loss = cluster_fairness_loss(alloc, apps, cluster)
    assert loss <= fairness_budget(cfg, cluster.m) + 1e-6


def test_adjustment_budget_respected():
    cluster, apps = small_cluster(), apps3()
    cfg = OptimizerConfig(0.3, 0.0)     # theta2=0: NO adjustments allowed
    opt = MilpOptimizer(cfg)
    prev = opt.solve(apps, cluster, None)
    # submit a 4th app; existing allocations must not change (budget 0)
    apps4 = apps + [ApplicationSpec("a4", "MxNet",
                                    ResourceVector.of(2, 0, 8), 1, 8, 1)]
    alloc = opt.solve(apps4, cluster, prev)
    if alloc is not None:
        assert resource_adjustment_overhead(prev, alloc) == 0


def test_infeasible_returns_none():
    cluster = ClusterSpec.homogeneous(1, ResourceVector.of(2, 0, 8))
    # n_min=4 containers of 2 CPUs each cannot fit in 2 CPUs
    apps = [ApplicationSpec("big", "x", ResourceVector.of(2, 0, 8), 1, 8, 4)]
    assert MilpOptimizer(OptimizerConfig()).solve(apps, cluster, None) is None
    assert GreedyOptimizer(OptimizerConfig()).solve(apps, cluster, None) is None


def test_milp_maximizes_utilization_simple():
    """One app, plenty of room -> n_max containers."""
    cluster = small_cluster(2)
    app = ApplicationSpec("solo", "x", ResourceVector.of(2, 0, 8), 1, 6, 1)
    alloc = MilpOptimizer(OptimizerConfig(1.0, 1.0)).solve([app], cluster, None)
    assert alloc.containers_of("solo") == 6


def test_stickiness_under_greedy():
    """Greedy keeps previous placements when nothing changed."""
    cluster, apps = small_cluster(), apps3()
    cfg = OptimizerConfig(0.2, 0.2)
    opt = GreedyOptimizer(cfg)
    a1 = opt.solve(apps, cluster, None)
    a2 = opt.solve(apps, cluster, a1)
    assert resource_adjustment_overhead(a1, a2) <= adjust_budget(cfg, 3)
