"""Perf-variant equivalence tests (EXPERIMENTS.md §Perf): the optimized
paths must be numerically identical to the paper-faithful baseline.

Multi-device shard_map variants run in a subprocess with 8 forced host
devices (the in-process suite keeps 1 device so smoke tests stay honest).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import init_params, loss_fn
from repro.models.config import ModelConfig
from repro.training.optimizer import OptimizerSpec
from repro.training.train_loop import init_train_state, make_train_step

TINY = ModelConfig("t", "dense", 2, 64, 2, 2, 128, 128, head_dim=32,
                   dtype="float32", attn_impl="ref")


@pytest.mark.parametrize("policy", ["full", "save_dots",
                                    "save_nothing_but_dots_with_no_batch"])
def test_remat_policies_same_numerics(policy):
    spec = OptimizerSpec(peak_lr=1e-3, warmup_steps=2, total_steps=10)
    state = init_train_state(jax.random.PRNGKey(0), TINY, spec)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 128)
    batch = {"tokens": toks, "labels": toks}
    base_state, base_m = make_train_step(TINY, spec, remat=True,
                                         remat_policy="full")(state, batch)
    new_state, new_m = make_train_step(TINY, spec, remat=True,
                                       remat_policy=policy)(state, batch)
    assert float(base_m["loss"]) == pytest.approx(float(new_m["loss"]),
                                                  rel=1e-6)
    for a, b in zip(jax.tree.leaves(base_state["params"]),
                    jax.tree.leaves(new_state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_ct_cast_is_identity_forward():
    cfg = TINY.with_overrides(bf16_cotangents=True)
    params = init_params(jax.random.PRNGKey(0), TINY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 128)
    batch = {"tokens": toks, "labels": toks}
    l0, _ = loss_fn(params, TINY, batch)
    l1, _ = loss_fn(params, cfg, batch)
    assert float(l0) == pytest.approx(float(l1), rel=1e-6)


SUBPROCESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, json
    from jax.sharding import Mesh
    from repro.models.config import ModelConfig
    from repro.models.moe import init_moe, moe_block
    from repro.models import meshctx, init_params, forward

    results = {}
    base = ModelConfig("m","moe",2,128,4,4,64,256,head_dim=32,
                       dtype="float32", num_experts=8, num_experts_per_tok=2,
                       capacity_factor=8.0, attn_impl="ref")
    p = init_moe(jax.random.PRNGKey(0), base, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1),(4,32,128))
    out_l, _ = moe_block(p, x, base)
    mesh = Mesh(np.array(jax.devices()).reshape(2,4), ("data","model"))
    with meshctx.use_mesh(mesh):
        for disp in ("psum","alltoall"):
            cfg = base.with_overrides(expert_axis="model", moe_dispatch=disp)
            out_e, _ = jax.jit(lambda p,x: moe_block(p,x,cfg))(p, x)
            results[f"moe_{disp}"] = float(jnp.abs(out_l-out_e).max())

    # shard_map TP projections == plain einsum path
    dense = ModelConfig("d","dense",2,128,8,8,256,256,head_dim=16,
                        dtype="float32", attn_impl="ref")
    params = init_params(jax.random.PRNGKey(0), dense)
    toks = jax.random.randint(jax.random.PRNGKey(1),(8,32),0,256)
    ref_logits, _ = forward(params, dense, {"tokens": toks})
    with meshctx.use_mesh(mesh):
        tp = dense.with_overrides(tp_axis="model")
        tp_logits, _ = jax.jit(lambda p,b: forward(p, tp, b))(
            params, {"tokens": toks})
    results["tp_shardmap"] = float(jnp.abs(ref_logits-tp_logits).max())
    print(json.dumps(results))
""")


@pytest.mark.slow
def test_shardmap_variants_match_reference_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SUBPROCESS],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["moe_psum"] < 1e-4
    assert res["moe_alltoall"] < 1e-4
    assert res["tp_shardmap"] < 1e-3
