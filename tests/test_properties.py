"""Hypothesis property-based tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis is not in the baked image (no pip install allowed); "
           "these property tests run wherever it is available")
from hypothesis import given, settings, strategies as st

from repro.core import (Allocation, ApplicationSpec, ClusterSpec,
                        GreedyOptimizer, MilpOptimizer, OptimizerConfig,
                        ResourceVector, cluster_fairness_loss,
                        drf_container_counts, fairness_budget,
                        resource_utilization, validate_allocation)
from repro.models.moe import expert_capacity
from repro.models.config import ModelConfig


# ------------------------------------------------------------- strategies

@st.composite
def cluster_and_apps(draw):
    b = draw(st.integers(1, 5))
    cap = ResourceVector.of(draw(st.integers(4, 16)),
                            draw(st.integers(0, 2)),
                            draw(st.integers(16, 64)))
    cluster = ClusterSpec.homogeneous(b, cap)
    n_apps = draw(st.integers(1, 5))
    apps = []
    for i in range(n_apps):
        d = ResourceVector.of(draw(st.integers(1, 4)),
                              draw(st.integers(0, 1)),
                              draw(st.integers(1, 16)))
        n_min = draw(st.integers(1, 2))
        n_max = draw(st.integers(n_min, n_min + 8))
        apps.append(ApplicationSpec(
            f"app{i}", "x", d, draw(st.integers(1, 4)), n_max, n_min))
    return cluster, apps


# ---------------------------------------------------------- DRF invariants

@given(cluster_and_apps())
@settings(max_examples=40, deadline=None)
def test_drf_counts_respect_capacity_and_bounds(ca):
    cluster, apps = ca
    counts = drf_container_counts(apps, cluster)
    total = np.zeros(cluster.m)
    for i, a in enumerate(apps):
        assert 0 <= counts[a.app_id] <= a.n_max
        total += counts[a.app_id] * a.demand.as_array()
    assert np.all(total <= cluster.total_capacity() + 1e-9)


# ------------------------------------------------------ optimizer invariants

@given(cluster_and_apps(), st.sampled_from([0.05, 0.1, 0.2, 0.5]))
@settings(max_examples=25, deadline=None)
def test_greedy_solution_feasible_and_within_budget(ca, theta1):
    cluster, apps = ca
    cfg = OptimizerConfig(theta1, 1.0)
    alloc = GreedyOptimizer(cfg).solve(apps, cluster, None)
    if alloc is None:       # infeasible is an allowed outcome
        return
    validate_allocation(alloc, apps, cluster)
    assert cluster_fairness_loss(alloc, apps, cluster) \
        <= fairness_budget(cfg, cluster.m) + 1e-6


@given(cluster_and_apps())
@settings(max_examples=10, deadline=None)
def test_milp_at_least_as_good_as_greedy(ca):
    cluster, apps = ca
    cfg = OptimizerConfig(0.2, 1.0, time_limit_s=5.0)
    a_g = GreedyOptimizer(cfg).solve(apps, cluster, None)
    a_m = MilpOptimizer(cfg).solve(apps, cluster, None)
    if a_g is not None and a_m is not None:
        assert resource_utilization(a_m, apps, cluster) \
            >= resource_utilization(a_g, apps, cluster) - 1e-6


# ------------------------------------------------------------ moe capacity

@given(st.integers(8, 4096), st.integers(1, 8), st.integers(2, 64),
       st.floats(1.0, 2.0))
@settings(max_examples=50, deadline=None)
def test_expert_capacity_covers_balanced_load(n, k, e, f):
    if k > e:
        return
    cfg = ModelConfig("t", "moe", 1, 64, 2, 2, 32, 64, num_experts=e,
                      num_experts_per_tok=k, capacity_factor=f)
    C = expert_capacity(n, cfg)
    # total slots must cover a perfectly balanced assignment
    assert C * e >= n * k
    assert C % 8 == 0
