"""Regression: the delta fast path must engage on replayed traces.

Philly-schema replays split whole-job demands across containers, so
per-container demand vectors are FRACTIONAL (e.g. 3 + 1/n_gpus cpus).
Before the canonical-free-vector fix in `GreedyOptimizer.solve`, the
SoA engine declined every delta solve the moment any admitted app had a
non-integral demand -- BENCH_replay.json showed 3317 full solves and 0
delta solves over a 2000-job trace.  This test replays a small fractional
trace and asserts the delta fraction is strictly positive AND the
incremental timeline is bit-exact against the full re-solve timeline
(allocation-for-allocation), which is what makes the fast path safe to
take."""
import numpy as np

from repro.core import (ClusterSimulator, DormMaster, OptimizerConfig,
                        PolicyTimer, Reallocated, RecordingProtocol,
                        heterogeneous_cluster, replay_trace)

N_APPS = 60
N_SLAVES = 120


def _synthetic_philly_csv(n_jobs: int, seed: int = 0) -> str:
    rng = np.random.default_rng(seed)
    lines = ["jobid,submitted_time,run_time,num_gpus,num_cpus,mem_gb"]
    t = 0.0
    for j in range(n_jobs):
        t += float(rng.exponential(90.0))
        n_gpus = int(rng.integers(1, 9))
        run_time = float(rng.uniform(600.0, 7200.0))
        lines.append(f"job-{j:04d},{t:.1f},{run_time:.1f},"
                     f"{n_gpus},{n_gpus * 3 + 1},{n_gpus * 20 + 5}")
    return "\n".join(lines) + "\n"


def _replay(incremental: bool):
    wl = replay_trace(_synthetic_philly_csv(N_APPS), fmt="philly")
    cluster = heterogeneous_cluster(N_SLAVES, seed=0)
    cfg = OptimizerConfig(0.2, 0.2, warm_start=True,
                          incremental=incremental, soa=True)
    master = DormMaster(cluster, "greedy", cfg,
                        protocol=RecordingProtocol())
    timer = PolicyTimer(master)
    sim = ClusterSimulator(timer, wl, adjustment_cost_s=60.0,
                           horizon_s=48 * 3600.0, batch_window_s=60.0)
    allocs = []
    sim.runtime.bus.subscribe(
        Reallocated,
        lambda e: allocs.append((e.t, e.result.allocation.app_ids,
                                 e.result.allocation.x.copy())))
    res = sim.run()
    return master, res, allocs


def test_replayed_fractional_trace_takes_delta_path():
    master, res, _ = _replay(incremental=True)
    greedy = master.optimizer
    # The regression itself: fractional demands used to force the delta
    # fraction to exactly zero (delta_solves == 0 over the whole replay).
    assert greedy.delta_solves > 0, \
        "delta fast path never engaged on a fractional replayed trace"
    total = greedy.delta_solves + greedy.full_solves
    assert greedy.delta_solves / total > 0.0
    # First event is always a full solve; the counter stays meaningful.
    assert greedy.full_solves > 0
    # Demands really were fractional (the point of the scenario).
    wl = replay_trace(_synthetic_philly_csv(N_APPS), fmt="philly")
    assert any((w.spec.demand.as_array()
                != np.floor(w.spec.demand.as_array())).any() for w in wl)
    unfinished = [a for a, rt in res.completions.items()
                  if rt.finished_at is None]
    assert not unfinished


def test_replayed_delta_timeline_matches_full_resolve():
    _, res_inc, al_inc = _replay(incremental=True)
    _, res_full, al_full = _replay(incremental=False)
    assert len(al_inc) == len(al_full)
    for (t1, ids1, x1), (t2, ids2, x2) in zip(al_inc, al_full):
        assert t1 == t2 and ids1 == ids2
        np.testing.assert_array_equal(x1, x2)
    assert res_inc.durations() == res_full.durations()
