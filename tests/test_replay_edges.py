"""Trace-replay edge cases (repro.core.replay): malformed CSV rows,
out-of-order submit times, and headerless alibaba corner cases must raise or
skip DETERMINISTICALLY -- never crash with an unrelated error or silently
reorder work."""
import pytest

from repro.core import ReplayConfig, replay_trace


# ------------------------------------------------------------ malformed rows

def test_philly_malformed_numeric_cell_raises_value_error():
    trace = ("jobid,submitted_time,run_time,num_gpus\n"
             "j1,0,3600,2\n"
             "j2,oops,3600,2\n")
    with pytest.raises(ValueError):
        replay_trace(trace, fmt="philly")


def test_philly_missing_required_column_raises_with_column_name():
    trace = "jobid,submitted_time,num_gpus\nj1,0,2\n"
    with pytest.raises(ValueError, match="run_time"):
        replay_trace(trace, fmt="philly")


def test_generic_malformed_row_raises():
    trace = ("app_id,submit_time,duration_s,cpus,gpus,ram_gb,n_min,n_max,"
             "weight\n"
             "a,0,100,not-a-number,0,4,1,2,1\n")
    with pytest.raises(ValueError):
        replay_trace(trace, fmt="generic")


def test_unknown_format_raises():
    with pytest.raises(ValueError, match="unknown trace format"):
        replay_trace("x,y\n1,2\n", fmt="borg")


# -------------------------------------------------- skip rules (not crashes)

def test_philly_zero_duration_and_zero_gpu_rows_skip():
    trace = ("jobid,submitted_time,run_time,num_gpus\n"
             "dead,0,0,2\n"          # zero duration: failed job
             "cpu,10,3600,0\n"       # zero GPUs
             "ok,20,3600,2\n")
    apps = replay_trace(trace, fmt="philly")
    assert [w.spec.app_id for w in apps] == ["ok"]


def test_alibaba_short_and_non_terminated_rows_skip():
    base = "t1,2,j1,1,Terminated,100,200,100,0.5"
    trace = "\n".join([
        base,
        "t2,2,j1,1",                              # short row: skipped
        "t3,2,j1,1,Failed,100,200,100,0.5",       # not Terminated
        "t4,1,j2,1,Terminated,300,200,100,0.5",   # end < start
        "t5,0,j2,1,Terminated,100,200,100,0.5",   # zero instances
    ]) + "\n"
    apps = replay_trace(trace, fmt="alibaba")
    assert [w.spec.app_id for w in apps] == ["j1/t1"]


# ----------------------------------------------------- ordering + shifting

def test_out_of_order_submit_times_sort_and_shift_to_zero():
    trace = ("jobid,submitted_time,run_time,num_gpus\n"
             "late,5000,3600,1\n"
             "early,1000,3600,2\n"
             "mid,2500,3600,1\n")
    apps = replay_trace(trace, fmt="philly")
    assert [w.spec.app_id for w in apps] == ["early", "mid", "late"]
    times = [w.spec.submit_time for w in apps]
    assert times == sorted(times)
    assert times[0] == 0.0                       # shifted to t=0
    assert times[2] == pytest.approx(4000.0)     # relative gaps preserved


def test_out_of_order_alibaba_headerless_sorts_deterministically():
    trace = ("t2,1,j,1,Terminated,900,1000,100,0.5\n"
             "t1,1,j,1,Terminated,100,300,100,0.5\n")
    apps = replay_trace(trace, fmt="alibaba")
    assert [w.spec.app_id for w in apps] == ["j/t1", "j/t2"]
    assert apps[0].spec.submit_time == 0.0


# ---------------------------------------------------- headerless alibaba

def test_alibaba_optional_header_row_accepted():
    headered = ("task_name,instance_num,job_name,task_type,status,"
                "start_time,end_time,plan_cpu,plan_mem\n"
                "t1,2,j1,1,Terminated,100,200,100,0.5\n")
    headerless = "t1,2,j1,1,Terminated,100,200,100,0.5\n"
    a = replay_trace(headered, fmt="alibaba")
    b = replay_trace(headerless, fmt="alibaba")
    assert len(a) == len(b) == 1
    assert a[0].spec == b[0].spec


def test_alibaba_empty_trace_raises_value_error():
    """Regression: an empty alibaba source used to crash with IndexError on
    the header probe; it must raise the same deterministic ValueError as
    the headered formats."""
    with pytest.raises(ValueError, match="empty trace"):
        replay_trace([], fmt="alibaba")
    with pytest.raises(ValueError):
        replay_trace([], fmt="philly")


# ------------------------------------------------- parser-hardening fixes

def test_generic_nmin_above_nmax_clamps_instead_of_crashing():
    """Regression: one malformed n_min > n_max row used to crash the WHOLE
    trace with a context-free ValueError from ApplicationSpec; it now
    clamps via the same min(n_min, n_max) rule as the philly/alibaba
    `_bounds` mapping and the rest of the trace replays."""
    trace = ("app_id,submit_time,duration_s,cpus,gpus,ram_gb,n_min,n_max,"
             "weight\n"
             "bad,0,100,2,0,4,5,2,1\n"       # n_min=5 > n_max=2
             "good,10,100,2,0,4,1,4,1\n")
    apps = replay_trace(trace, fmt="generic")
    assert sorted(w.spec.app_id for w in apps) == ["bad", "good"]
    (bad,) = [w.spec for w in apps if w.spec.app_id == "bad"]
    assert (bad.n_min, bad.n_max) == (2, 2)


def test_generic_still_invalid_row_raises_with_row_context():
    """A row that is invalid even after clamping (negative demand) must
    name itself -- row number and contents -- not surface a bare spec
    error."""
    trace = ("app_id,submit_time,duration_s,cpus,gpus,ram_gb,n_min,n_max,"
             "weight\n"
             "ok,0,100,2,0,4,1,2,1\n"
             "neg,5,100,-3,0,4,1,2,1\n")
    with pytest.raises(ValueError, match=r"generic: row 3.*neg"):
        replay_trace(trace, fmt="generic")


def test_generic_truncated_row_raises_with_row_context():
    """A truncated row (fewer cells than the header) must raise the same
    contextual ValueError, not a bare IndexError from the column lookup
    (app_id mapped to the last column makes the lookup fall off the row)."""
    trace = ("submit_time,duration_s,cpus,gpus,ram_gb,n_min,n_max,weight,"
             "app_id\n"
             "5,100,2,0,4,1,2\n")
    with pytest.raises(ValueError, match=r"generic: row 2"):
        replay_trace(trace, fmt="generic")


def test_alibaba_empty_status_rows_skip():
    """Regression: rows with an EMPTY status field used to replay even
    though the docstring promises only `Terminated` tasks do."""
    trace = ("t1,2,j1,1,Terminated,100,200,100,0.5\n"
             "t2,2,j1,1,,100,200,100,0.5\n"          # empty status
             "t3,2,j1,1,  ,100,200,100,0.5\n")       # whitespace status
    apps = replay_trace(trace, fmt="alibaba")
    assert [w.spec.app_id for w in apps] == ["j1/t1"]


def test_philly_explicit_zero_cpu_mem_cells_fall_back_to_defaults():
    """Regression: explicit num_cpus=0 / mem_gb=0 cells used to produce
    zero-CPU/zero-RAM container demands (the `_f` default only covered
    missing or empty cells), so replayed apps consumed only GPU capacity;
    they now fall back to the per-GPU defaults exactly like empty cells."""
    cfg = ReplayConfig(cpus_per_gpu=4.0, ram_per_gpu_gb=32.0)
    trace = ("jobid,submitted_time,run_time,num_gpus,num_cpus,mem_gb\n"
             "zero,0,3600,2,0,0\n"
             "empty,10,3600,2,,\n"
             "real,20,3600,2,6,50\n")
    apps = {w.spec.app_id: w.spec
            for w in replay_trace(trace, fmt="philly", cfg=cfg)}
    assert apps["zero"].demand.values == (4.0, 1.0, 32.0)
    assert apps["zero"].demand.values == apps["empty"].demand.values
    assert apps["real"].demand.values == (3.0, 1.0, 25.0)


def test_alibaba_demand_mapping_and_elasticity_bounds():
    cfg = ReplayConfig(min_fraction=0.5, ram_unit_gb=64.0)
    trace = "t1,8,j1,1,Terminated,0,1000,250,0.25\n"
    (w,) = replay_trace(trace, fmt="alibaba", cfg=cfg)
    assert w.spec.demand.values == (2.5, 0.0, 16.0)   # plan_cpu/100, mem*64
    assert w.spec.n_max == 8 and w.spec.n_min == 4    # ceil(8 * 0.5)
    assert w.spec.serial_work == pytest.approx(1000.0 * 8)
