"""Replay-driven XL benchmark smoke (the test half of the ROADMAP's
"replay-driven XL benchmarks" item).

Generates a synthetic Philly-schema CSV in-test, replays it at 500 slaves
x 200 jobs through `bench_scale`-style timing (auto optimizer, SoA engine,
event batching, PolicyTimer, churn subscriber). The jobs carry FRACTIONAL
per-container demands (num_cpus not divisible by num_gpus); the delta
fast path canonicalizes the free-capacity vector and serves these events
too (see tests/test_replay_delta.py for the dedicated regression).
Asserts every app completes and the churn/latency metrics are finite.

CI runs a scaled-down version of the same test: the size is overridable
via REPLAY_SMOKE_SLAVES / REPLAY_SMOKE_APPS (see .github/workflows/ci.yml).
"""
import math
import os

import numpy as np

from repro.core import (ClusterSimulator, DormMaster, OptimizerConfig,
                        PolicyTimer, Reallocated, RecordingProtocol,
                        container_churn, heterogeneous_cluster, replay_trace)

N_SLAVES = int(os.environ.get("REPLAY_SMOKE_SLAVES", "500"))
N_APPS = int(os.environ.get("REPLAY_SMOKE_APPS", "200"))


def _synthetic_philly_csv(n_jobs: int, seed: int = 0) -> str:
    """Philly-schema rows (jobid,submitted_time,run_time,num_gpus,
    num_cpus,mem_gb) with deliberately fractional per-container demands:
    num_cpus/mem_gb are NOT multiples of num_gpus, so replay's
    demand-per-container split produces non-integral vectors."""
    rng = np.random.default_rng(seed)
    lines = ["jobid,submitted_time,run_time,num_gpus,num_cpus,mem_gb"]
    t = 0.0
    for j in range(n_jobs):
        t += float(rng.exponential(90.0))
        n_gpus = int(rng.integers(1, 9))
        run_time = float(rng.uniform(600.0, 7200.0))
        n_cpus = n_gpus * 3 + 1          # 3 + 1/n_gpus cpus per container
        mem = n_gpus * 20 + 5            # 20 + 5/n_gpus GB per container
        lines.append(f"job-{j:04d},{t:.1f},{run_time:.1f},"
                     f"{n_gpus},{n_cpus},{mem}")
    return "\n".join(lines) + "\n"


def test_replay_xl_smoke_fractional_demands_complete():
    wl = replay_trace(_synthetic_philly_csv(N_APPS), fmt="philly")
    assert len(wl) == N_APPS
    # Fractional demands actually materialized (the point of the scenario).
    assert any((w.spec.demand.as_array()
                != np.floor(w.spec.demand.as_array())).any() for w in wl)

    cluster = heterogeneous_cluster(N_SLAVES, seed=0)
    cfg = OptimizerConfig(0.2, 0.2, warm_start=True, incremental=True,
                          soa=True)
    # Pinned to the greedy solver (not "auto"): the test's point is the
    # NON-DELTA greedy path under fractional demands, and it must keep
    # making that point at any REPLAY_SMOKE_* size -- "auto" would switch
    # to MILP below auto_switch_vars and void the assertions.
    master = DormMaster(cluster, "greedy", cfg,
                        protocol=RecordingProtocol())
    timer = PolicyTimer(master)
    sim = ClusterSimulator(timer, wl, adjustment_cost_s=60.0,
                           horizon_s=48 * 3600.0, batch_window_s=60.0)
    churn = {"total": 0, "last": None}

    def on_realloc(ev):
        churn["total"] += container_churn(churn["last"],
                                          ev.result.allocation)
        churn["last"] = ev.result.allocation

    sim.runtime.bus.subscribe(Reallocated, on_realloc)
    res = sim.run()

    # Every replayed job finishes inside the horizon.
    unfinished = [a for a, rt in res.completions.items()
                  if rt.finished_at is None]
    assert not unfinished, f"{len(unfinished)} jobs unfinished: " \
                           f"{unfinished[:5]}"
    # Fractional demands no longer disable the delta fast path on the SoA
    # engine (the free vector is canonicalized instead); the first event
    # and every churny event still full-solve, and steady-state events
    # ride the delta path.
    greedy = master.optimizer
    assert greedy.full_solves > 0
    assert greedy.delta_solves > 0

    # Churn and timing metrics are finite and sane.
    assert math.isfinite(churn["total"]) and churn["total"] >= 0
    assert math.isfinite(res.time_averaged_utilization())
    assert math.isfinite(res.mean_fairness_loss())
    assert math.isfinite(timer.total_s()) and timer.n_calls > 0
    assert math.isfinite(timer.median_ms())
    assert res.total_adjustments >= 0
