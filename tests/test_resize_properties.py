"""Property-based resize-storm suite.

Random interleavings of Arrival / Completion / Resize events -- grows,
shrinks, explicit n_min > n_max rejections, resizes of already-finished
apps -- driven through FOUR DormMaster configurations simultaneously
(SoA/legacy engine x incremental/full re-solve). Invariants, after every
single event:

  * per-slave capacity is never exceeded,
  * every placed app holds n_min <= count <= n_max (unconditional, thanks
    to the reject-infeasible-resize semantics: bounds and allocations can
    never diverge),
  * the four engines are bit-exact event-for-event: same allocation
    matrices, same adjusted/started/pending sets, metrics to 1e-9 (the
    engines sum Eq-2 in different float orders),
  * an invalid resize raises identically everywhere and mutates nothing.

Runs under hypothesis when available (CI installs it; 200+ examples);
falls back to a seeded-random sweep of the same check otherwise, so the
suite executes even on bare images."""
import numpy as np
import pytest

from repro.core import (ApplicationSpec, ClusterRuntime, ClusterSpec,
                        DormMaster, OptimizerConfig, Reallocated,
                        RecordingProtocol, Resize, ResourceVector,
                        TraceConfig, WorkloadApp, generate_trace,
                        heterogeneous_cluster)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

N_EXAMPLES = 220          # acceptance floor is 200+

THETAS = ((0.2, 0.2), (1.0, 1.0), (0.1, 0.3))


def _masters(cluster, theta):
    """(soa, incremental) x {True, False}^2 behind identical configs."""
    out = {}
    for soa in (True, False):
        for inc in (True, False):
            cfg = OptimizerConfig(*theta, incremental=inc, soa=soa)
            out[(soa, inc)] = DormMaster(cluster, "greedy", cfg,
                                         protocol=RecordingProtocol())
    return out


def _gen_ops(rng):
    """A random event script over a small cluster: (cluster, theta, ops).

    Ops reference sensible app ids (completions of running apps, resizes
    of running AND finished apps, occasional invalid bounds)."""
    b = int(rng.integers(2, 5))
    cap = ResourceVector.of(int(rng.integers(6, 14)),
                            int(rng.integers(0, 3)),
                            int(rng.integers(16, 49)))
    cluster = ClusterSpec.homogeneous(b, cap)
    theta = THETAS[int(rng.integers(len(THETAS)))]

    ops = []
    alive, finished = [], []
    next_id = 0
    for _ in range(int(rng.integers(8, 17))):
        choices = ["arrive"]
        if alive:
            choices += ["complete", "resize", "resize", "shrink"]
        if finished:
            choices.append("resize_finished")
        if alive and rng.random() < 0.15:
            choices.append("bad_resize")
        op = choices[int(rng.integers(len(choices)))]
        if op == "arrive":
            n_min = int(rng.integers(1, 3))
            n_max = n_min + int(rng.integers(0, 7))
            spec = ApplicationSpec(
                f"a{next_id}", "x",
                ResourceVector.of(int(rng.integers(1, 4)),
                                  int(rng.integers(0, 2)),
                                  int(rng.integers(1, 13))),
                int(rng.integers(1, 4)), n_max, n_min)
            next_id += 1
            alive.append(spec.app_id)
            ops.append(("arrive", spec))
        elif op == "complete":
            app = alive.pop(int(rng.integers(len(alive))))
            finished.append(app)
            ops.append(("complete", app))
        elif op in ("resize", "shrink"):
            app = alive[int(rng.integers(len(alive)))]
            if op == "shrink":
                lo = 1
                hi = int(rng.integers(1, 4))            # often below count
            else:
                lo = int(rng.integers(1, 5))
                hi = lo + int(rng.integers(0, 9))
            # Exercise the None-keeps-a-bound paths too.
            which = rng.random()
            if which < 0.25:
                ops.append(("resize", app, lo, None))
            elif which < 0.5:
                ops.append(("resize", app, None, hi))
            else:
                ops.append(("resize", app, lo, hi))
        elif op == "resize_finished":
            app = finished[int(rng.integers(len(finished)))]
            ops.append(("resize", app, 1, int(rng.integers(1, 9))))
        else:  # bad_resize: explicit inconsistent pair
            app = alive[int(rng.integers(len(alive)))]
            hi = int(rng.integers(1, 4))
            ops.append(("bad_resize", app, hi + int(rng.integers(1, 5)), hi))
    return cluster, theta, ops


def _apply(master, op):
    kind = op[0]
    if kind == "arrive":
        return master.on_arrival((op[1],))
    if kind == "complete":
        return master.on_completion(op[1])
    return master.on_resize(op[1], op[2], op[3])


def _check_invariants(master, cluster):
    """Capacity + bounds invariants from the master's own view."""
    cap = cluster.capacity_matrix()
    used = np.zeros_like(cap, dtype=np.float64)
    for app_id in list(master.partitions):
        spec = master.specs[app_id]
        if master.state is not None:
            row = master.state.placement(app_id)
        else:
            row = master._placements[app_id]
        count = int(row.sum())
        assert spec.n_min <= count <= spec.n_max, \
            f"{app_id}: count {count} outside [{spec.n_min}, {spec.n_max}]"
        used += row[:, None] * spec.demand.as_array()[None, :]
    assert np.all(used <= cap + 1e-6), "per-slave capacity exceeded"


def _check_storm(seed: int) -> None:
    rng = np.random.default_rng(seed)
    cluster, theta, ops = _gen_ops(rng)
    masters = _masters(cluster, theta)
    ref_key = (True, True)
    for op in ops:
        results = {}
        if op[0] == "bad_resize":
            for key, m in masters.items():
                before = {a: (s.n_min, s.n_max, m.containers_of(a))
                          for a, s in m.specs.items()}
                with pytest.raises(ValueError):
                    m.on_resize(op[1], op[2], op[3])
                after = {a: (s.n_min, s.n_max, m.containers_of(a))
                         for a, s in m.specs.items()}
                assert before == after, "failed resize mutated state"
            continue
        for key, m in masters.items():
            results[key] = _apply(m, op)
            _check_invariants(m, cluster)
        ref = results[ref_key]
        for key, res in results.items():
            if key == ref_key:
                continue
            assert (res is None) == (ref is None), (op, key)
            if ref is None:
                continue
            assert res.allocation.app_ids == ref.allocation.app_ids, (op, key)
            np.testing.assert_array_equal(res.allocation.x, ref.allocation.x,
                                          err_msg=f"{op} {key}")
            assert res.adjusted_app_ids == ref.adjusted_app_ids, (op, key)
            assert res.started_app_ids == ref.started_app_ids, (op, key)
            assert res.pending_app_ids == ref.pending_app_ids, (op, key)
            assert res.adjustment_overhead == ref.adjustment_overhead
            assert res.utilization == pytest.approx(ref.utilization,
                                                    abs=1e-9)
            assert res.fairness_loss == pytest.approx(ref.fairness_loss,
                                                      abs=1e-9)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2 ** 32 - 1))
    @settings(max_examples=N_EXAMPLES, deadline=None)
    def test_resize_storm_engines_bit_exact(seed):
        _check_storm(seed)
else:
    @pytest.mark.parametrize("chunk", range(11))
    def test_resize_storm_engines_bit_exact(chunk):
        # Seeded fallback: same check, 11 chunks x 20 seeds = 220 examples.
        for k in range(20):
            _check_storm(chunk * 20 + k)


# ------------------------------------------- runtime-level resize storms

def _run_timeline(cluster, wl, resizes, soa, incremental):
    cfg = OptimizerConfig(0.2, 0.2, incremental=incremental, soa=soa)
    m = DormMaster(cluster, "greedy", cfg, protocol=RecordingProtocol())
    rt = ClusterRuntime(m, horizon_s=24 * 3600.0)
    rt.inject(*resizes)
    allocs = []
    rt.bus.subscribe(Reallocated,
                     lambda e: allocs.append((e.t,
                                              e.result.allocation.app_ids,
                                              e.result.allocation.x.copy())))
    res = rt.run(wl)
    return res, allocs


def _check_runtime_storm(seed: int) -> None:
    """Full-timeline variant: generator trace + injected Resize storm; the
    incremental/full and SoA/legacy timelines stay identical event-for-
    event, including completions racing resizes."""
    rng = np.random.default_rng(seed)
    cluster = heterogeneous_cluster(int(rng.integers(8, 25)),
                                    seed=int(seed) % 13)
    wl = generate_trace(TraceConfig(n_apps=int(rng.integers(10, 26)),
                                    seed=seed,
                                    mean_interarrival_s=300.0))
    resizes = []
    for _ in range(int(rng.integers(3, 9))):
        w = wl[int(rng.integers(len(wl)))]
        t = w.spec.submit_time + float(rng.uniform(0, 2 * 3600.0))
        if rng.random() < 0.5:
            resizes.append(Resize(t, w.spec.app_id,
                                  n_max=int(rng.integers(1, 5))))   # shrink
        else:
            lo = int(rng.integers(1, 5))
            resizes.append(Resize(t, w.spec.app_id, lo,
                                  lo + int(rng.integers(0, 9))))
    runs = {
        (soa, inc): _run_timeline(cluster, wl, resizes, soa, inc)
        for soa in (True, False) for inc in (True, False)}
    res_ref, al_ref = runs[(True, True)]
    for key, (res, al) in runs.items():
        if key == (True, True):
            continue
        assert len(al) == len(al_ref), key
        for (t1, ids1, x1), (t2, ids2, x2) in zip(al, al_ref):
            assert t1 == t2 and ids1 == ids2, key
            np.testing.assert_array_equal(x1, x2, err_msg=str(key))
        assert res.durations() == res_ref.durations(), key
        assert len(res.samples) == len(res_ref.samples)
        for sa, sb in zip(res.samples, res_ref.samples):
            assert sa.t == sb.t
            assert sa.running == sb.running and sa.pending == sb.pending
            assert sa.adjustment_overhead == sb.adjustment_overhead
            assert sa.utilization == pytest.approx(sb.utilization, abs=1e-9)
            assert sa.fairness_loss == pytest.approx(sb.fairness_loss,
                                                     abs=1e-9)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2 ** 32 - 1))
    @settings(max_examples=10, deadline=None)
    def test_runtime_resize_storm_timelines_identical(seed):
        _check_runtime_storm(seed)
else:
    @pytest.mark.parametrize("seed", range(6))
    def test_runtime_resize_storm_timelines_identical(seed):
        _check_runtime_storm(seed)


def test_resize_of_finished_app_returns_none_everywhere():
    cluster = ClusterSpec.homogeneous(2, ResourceVector.of(8, 0, 32))
    for key, m in _masters(cluster, (0.2, 0.2)).items():
        spec = ApplicationSpec("a", "x", ResourceVector.of(2, 0, 8), 1, 4, 1)
        m.on_arrival((spec,))
        m.on_completion("a")
        assert m.on_resize("a", 1, 8) is None, key
