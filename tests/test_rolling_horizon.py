"""Rolling-horizon exact solve (MilpOptimizer past cfg.rolling_horizon_vars):
feasibility at >= 5k variables, objective within 1% of the monolithic MILP on
instances small enough to solve both ways, and budget-split correctness."""
import numpy as np
import pytest

from repro.core import (Allocation, ApplicationSpec, ClusterSpec,
                        MilpOptimizer, OptimizerConfig, ResourceVector,
                        adjust_budget, fairness_budget, resource_utilization,
                        validate_allocation)

pytest.importorskip("scipy")


def _apps(n, seed=0, nmax=8):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        out.append(ApplicationSpec(
            f"a{i}", "x",
            ResourceVector.of(int(rng.integers(1, 4)), 0,
                              int(rng.integers(2, 9))),
            int(rng.integers(1, 3)), nmax, 1))
    return out


def test_rolling_matches_monolithic_objective_within_1pct():
    """Same instance solved monolithically and with a forced tiny block
    size: the decomposed objective lands within 1% (usually exactly)."""
    cluster = ClusterSpec.homogeneous(6, ResourceVector.of(16, 0, 64))
    apps = _apps(8, seed=1)
    mono = MilpOptimizer(OptimizerConfig(0.2, 0.2, rolling_horizon_vars=0))
    roll = MilpOptimizer(OptimizerConfig(0.2, 0.2, rolling_horizon_vars=18))
    a_m = mono.solve(apps, cluster, None)
    a_r = roll.solve(apps, cluster, None)
    assert mono.monolithic_solves == 1 and roll.rolling_solves == 1
    assert a_m is not None and a_r is not None
    validate_allocation(a_r, apps, cluster)
    u_m = resource_utilization(a_m, apps, cluster)
    u_r = resource_utilization(a_r, apps, cluster)
    assert u_r >= u_m * 0.99 - 1e-9


def test_rolling_solves_5k_variable_instance():
    """>= 5000 x-variables (the open ROADMAP item was ~2k): the rolling
    path must return a feasible allocation in bounded time."""
    cluster = ClusterSpec.homogeneous(100, ResourceVector.of(32, 0, 128))
    apps = _apps(52, seed=2, nmax=6)            # 52 * 100 = 5200 vars
    opt = MilpOptimizer(OptimizerConfig(0.2, 0.2, time_limit_s=10.0,
                                        rolling_horizon_vars=2000))
    alloc = opt.solve(apps, cluster, None)
    assert opt.rolling_solves == 1
    assert alloc is not None
    validate_allocation(alloc, apps, cluster)
    # abundant aggregate capacity: the exact path must saturate every app
    # at n_max (the DRF target), i.e. zero fairness loss and max objective
    assert (alloc.x.sum(axis=1)
            == np.array([a.n_max for a in apps])).all()


def test_rolling_respects_global_budgets_vs_prev():
    """With a previous allocation, the union of the block solutions must
    honor the GLOBAL Eq-15/Eq-16 budgets (the splits sum exactly)."""
    cluster = ClusterSpec.homogeneous(10, ResourceVector.of(16, 0, 64))
    apps = _apps(12, seed=3, nmax=6)
    cfg = OptimizerConfig(0.2, 0.2, rolling_horizon_vars=40)
    opt = MilpOptimizer(cfg)
    first = opt.solve(apps, cluster, None)
    assert first is not None
    # shrink one app's row artificially to force re-adjustment pressure
    x0 = first.x.copy()
    busy = int(np.argmax(x0.sum(axis=1)))
    x0[busy] = 0
    x0[busy, 0] = 1
    prev = Allocation(first.app_ids, x0)
    second = opt.solve(apps, cluster, prev)
    assert second is not None
    validate_allocation(second, apps, cluster)
    changed = sum(1 for i in range(len(apps))
                  if not np.array_equal(second.x[i], prev.x[i]))
    assert changed <= adjust_budget(cfg, len(apps))
    # Eq-15 (evaluated against the solver's own targets)
    from repro.core.optimizer import _dominant_coeff
    g = _dominant_coeff(apps, cluster)
    s_hat = opt.last_shares_vec
    loss = float(np.abs(g * second.x.sum(axis=1) - s_hat).sum())
    assert loss <= fairness_budget(cfg, cluster.m) + 1e-6


def test_rolling_disabled_keeps_monolithic_path():
    cluster = ClusterSpec.homogeneous(50, ResourceVector.of(16, 0, 64))
    apps = _apps(10, seed=4)
    opt = MilpOptimizer(OptimizerConfig(0.2, 0.2, rolling_horizon_vars=0,
                                        time_limit_s=10.0))
    alloc = opt.solve(apps, cluster, None)
    assert opt.rolling_solves == 0 and opt.monolithic_solves == 1
    assert alloc is not None
