"""Tests for the shared event-driven cluster runtime: typed event bus,
SchedulerPolicy conformance of every cluster manager, Resize/Tick event
handling, trace replay, and the live-training bridge."""
import numpy as np
import pytest

from repro.core import (ApplicationSpec, Arrival, ClusterRuntime,
                        ClusterSimulator, ClusterSpec, Completion,
                        DRFScheduler, DormMaster, EventBus, MetricsLogger,
                        OptimizerConfig, PolicyTimer, Reallocated,
                        RecordingProtocol, ReplayConfig, Resize,
                        ResourceVector, SchedulerPolicy, StaticScheduler,
                        Tick, TraceConfig, WorkloadApp, as_policy,
                        generate_trace, generate_workload,
                        heterogeneous_cluster, paper_testbed, replay_trace)


def _cluster(n=4, cap=(8, 0, 32)):
    return ClusterSpec.homogeneous(n, ResourceVector.of(*cap))


def _app(i, cpus=2, ram=8, nmin=1, nmax=4, work=4 * 3600.0, t=0.0):
    return ApplicationSpec(f"app{i}", "x", ResourceVector.of(cpus, 0, ram),
                           1, nmax, nmin, serial_work=work, submit_time=t)


def _wl(*specs):
    return [WorkloadApp(spec=s, class_index=0,
                        base_duration_s=s.serial_work) for s in specs]


def _dorm(cluster, theta=(0.2, 0.2)):
    return DormMaster(cluster, "greedy", OptimizerConfig(*theta),
                      protocol=RecordingProtocol())


# ---------------------------------------------------------------- event bus

def test_event_bus_dispatches_by_type():
    bus = EventBus()
    got = []
    bus.subscribe(Arrival, lambda e: got.append(("arr", e.t)))
    bus.subscribe(Completion, lambda e: got.append(("fin", e.t)))
    bus.publish(Arrival(1.0, ()))
    bus.publish(Completion(2.0, "a"))
    bus.publish(Tick(3.0))                    # no subscriber: ignored
    assert got == [("arr", 1.0), ("fin", 2.0)]


def test_every_cluster_manager_implements_scheduler_policy():
    cluster = _cluster()
    policies = [
        _dorm(cluster),
        StaticScheduler(cluster, {}),
        DRFScheduler(cluster),
    ]
    for p in policies:
        assert isinstance(p, SchedulerPolicy)
        assert as_policy(p) is p              # no adapter needed


def test_as_policy_adapts_legacy_scheduler():
    class Legacy:
        def __init__(self):
            self.log = []

        def submit(self, spec):
            self.log.append(("submit", spec.app_id))
            return None

        def complete(self, app_id):
            self.log.append(("complete", app_id))
            return None

        def containers_of(self, app_id):
            return 0

    legacy = Legacy()
    pol = as_policy(legacy)
    assert pol is not legacy
    pol.on_arrival((_app(1),))
    pol.on_completion("app1")
    assert legacy.log == [("submit", "app1"), ("complete", "app1")]
    assert pol.on_tick(0.0) is None
    with pytest.raises(TypeError):
        as_policy(object())


# ------------------------------------------------------------ runtime loop

def test_runtime_emits_typed_events_on_bus():
    cluster = _cluster()
    wl = _wl(_app(1, t=100.0, work=3600.0), _app(2, t=200.0, work=3600.0))
    sim = ClusterSimulator(_dorm(cluster), wl, horizon_s=24 * 3600)
    seen = []
    sim.runtime.bus.subscribe(Arrival, lambda e: seen.append(("arr", e.t)))
    sim.runtime.bus.subscribe(Completion,
                              lambda e: seen.append(("fin", e.app_id)))
    sim.runtime.bus.subscribe(Reallocated,
                              lambda e: seen.append(("realloc", e.t)))
    res = sim.run()
    kinds = [k for k, _ in seen]
    assert kinds.count("arr") == 2
    assert kinds.count("fin") == 2
    assert kinds.count("realloc") == len(res.samples)


def test_resize_event_rebounds_running_app():
    """Injected Resize narrows a running app's n_max; the policy shrinks its
    partition through the adjustment protocol (and reports it adjusted)."""
    cluster = _cluster()
    wl = _wl(_app(1, nmax=8, work=200 * 3600.0, t=0.0))
    master = _dorm(cluster, theta=(1.0, 1.0))
    rt = ClusterRuntime(master, horizon_s=24 * 3600)
    rt.inject(Resize(3600.0, "app1", n_max=2))
    res = rt.run(wl)
    assert master.containers_of("app1") == 2
    assert master.specs["app1"].n_max == 2
    # the resize produced a sample with the app adjusted
    resize_samples = [s for s in res.samples if s.t == 3600.0]
    assert resize_samples and resize_samples[0].adjustment_overhead == 1


def test_resize_below_n_min_clamps_bounds():
    """Capping n_max below the current n_min must clamp, not crash the
    event loop (and vice versa for raising n_min past n_max)."""
    spec = _app(1, nmin=2, nmax=8)
    assert spec.with_bounds(n_max=1).n_min == 1
    assert spec.with_bounds(n_min=12).n_max == 12
    with pytest.raises(ValueError):
        spec.with_bounds(n_min=5, n_max=2)       # explicit inconsistency

    cluster = _cluster()
    wl = _wl(_app(1, nmin=2, nmax=8, work=200 * 3600.0))
    master = _dorm(cluster, theta=(1.0, 1.0))
    rt = ClusterRuntime(master, horizon_s=12 * 3600)
    rt.inject(Resize(3600.0, "app1", n_max=1))
    rt.run(wl)                                   # must not raise
    assert master.specs["app1"].n_max == 1
    assert master.specs["app1"].n_min == 1
    assert master.containers_of("app1") == 1


def test_resize_with_zero_adjust_budget_is_rejected():
    """A shrink-resize under a zero Eq-16 budget cannot be enforced (the
    shrink IS an adjustment); the resize must be REJECTED -- bounds revert,
    allocation untouched -- rather than crash or stick as an unenforceable
    bound that would wedge every later solve."""
    cluster = _cluster(8)
    specs = [_app(i, nmax=4, work=200 * 3600.0, t=10.0 * i)
             for i in range(3)]
    master = DormMaster(
        cluster, "greedy",
        OptimizerConfig(1.0, 0.0, ceil_adjust_budget=False),
        protocol=RecordingProtocol())
    rt = ClusterRuntime(master, horizon_s=3600.0)
    rt.inject(Resize(100.0, "app0", n_max=1))
    rt.run(_wl(*specs))                          # must not raise
    spec = master.specs["app0"]
    assert (spec.n_min, spec.n_max) == (1, 4)    # rejected: bounds reverted
    assert spec.n_min <= master.containers_of("app0") <= spec.n_max


def test_runtime_rejects_batching_for_legacy_scheduler():
    class Legacy:
        def submit(self, spec):
            return None

        def complete(self, app_id):
            return None

        def containers_of(self, app_id):
            return 0

    with pytest.raises(ValueError, match="submit_batch"):
        ClusterRuntime(Legacy(), batch_window_s=60.0)


def test_resize_event_for_finished_app_is_skipped():
    cluster = _cluster()
    wl = _wl(_app(1, nmax=4, work=3600.0, t=0.0))      # finishes early
    master = _dorm(cluster, theta=(1.0, 1.0))
    rt = ClusterRuntime(master, horizon_s=24 * 3600)
    rt.inject(Resize(20 * 3600.0, "app1", n_max=2))
    res = rt.run(wl)
    assert master.specs.get("app1") is None            # completed + released
    assert all(s.t < 20 * 3600.0 for s in res.samples)


def test_tick_interval_triggers_periodic_rebalance():
    cluster = _cluster()
    wl = _wl(_app(1, nmax=8, work=40 * 3600.0, t=0.0))
    master = _dorm(cluster, theta=(1.0, 1.0))
    rt = ClusterRuntime(master, horizon_s=10 * 3600, tick_interval_s=3600.0)
    ticks = []
    rt.bus.subscribe(Tick, lambda e: ticks.append(e.t))
    rt.run(wl)
    assert len(ticks) == 10                    # one per hour of horizon
    assert ticks == sorted(ticks)


def test_policy_timer_records_calls():
    cluster = _cluster()
    wl = _wl(_app(1, t=10.0, work=3600.0), _app(2, t=20.0, work=3600.0))
    timer = PolicyTimer(_dorm(cluster))
    ClusterSimulator(timer, wl, horizon_s=24 * 3600).run()
    assert timer.n_calls == 4                  # 2 arrivals + 2 completions
    by_kind = timer.by_kind()
    assert set(by_kind) == {"arrival", "completion"}
    assert timer.total_s() > 0
    assert timer.mean_ms() > 0


def test_telemetry_attach_logs_event_stream():
    cluster = _cluster()
    wl = _wl(_app(1, t=10.0, work=3600.0))
    logger = MetricsLogger()
    sim = ClusterSimulator(_dorm(cluster), wl, horizon_s=24 * 3600,
                           logger=logger)
    logger.attach(sim.runtime.bus)
    sim.run()
    events = [e["event"] for e in logger.of_kind("event")]
    assert events == ["arrival", "reallocated", "completion", "reallocated"]
    assert len(logger.of_kind("sample")) == 2


# ------------------------------------------------------- baseline policies

def test_drf_scheduler_runs_through_runtime_and_churns():
    """The Mesos/YARN-style DRF baseline reallocates freely: same runtime,
    DRF-level fairness, but far more Eq-4 adjustments than Dorm."""
    wl = generate_workload(seed=2)[:15]
    cluster = paper_testbed()
    drf_res = ClusterSimulator(DRFScheduler(cluster), wl,
                               horizon_s=24 * 3600).run()
    dorm_res = ClusterSimulator(_dorm(cluster), wl,
                                horizon_s=24 * 3600).run()
    assert len(drf_res.durations()) >= len(dorm_res.durations()) - 2
    assert drf_res.total_adjustments > dorm_res.total_adjustments
    # DRF keeps fairness loss at the DRF point (small), like Dorm.
    assert drf_res.mean_fairness_loss() < 1.0


def test_static_scheduler_handles_batched_arrivals():
    cfg = TraceConfig(n_apps=40, seed=5, mean_interarrival_s=120.0,
                      serving_fraction=0.8, burst_prob=0.5)
    wl = generate_trace(cfg)
    cluster = heterogeneous_cluster(30, seed=0)
    static = {w.spec.app_id: w.spec.n_min for w in wl}
    res = ClusterSimulator(StaticScheduler(cluster, dict(static)), wl,
                           horizon_s=24 * 3600,
                           batch_window_s=300.0).run()
    assert res.total_adjustments == 0
    assert len(res.samples) > 0


# ------------------------------------------------------------ trace replay

PHILLY_CSV = """jobid,submitted_time,run_time,num_gpus,extra
j1,1000,3600,4,x
j2,400,7200,1,y
j3,900,0,2,z
j4,500,1800,0,w
"""

ALIBABA_CSV = """t1,4,j100,A,Terminated,86400,90000,200,0.5
t2,2,j101,A,Failed,86400,90000,100,0.5
t3,1,j102,A,Terminated,86500,86800,50,0.25
"""

GENERIC_CSV = """app_id,submit_time,duration_s,cpus,gpus,ram_gb,n_min,n_max,weight
a,10,600,2,0,8,1,4,1
b,0,1200,4,1,16,2,8,2
"""


def test_replay_philly_format():
    wl = replay_trace(PHILLY_CSV, fmt="philly")
    assert [w.spec.app_id for w in wl] == ["j2", "j1"]   # sorted, j3/j4 drop
    assert wl[0].spec.submit_time == 0.0                 # shifted to t=0
    assert wl[1].spec.submit_time == 600.0
    j1 = wl[1].spec
    assert j1.n_max == 4 and j1.n_min == 1               # 4 * 0.25
    assert j1.demand.values[1] == 1.0                    # one GPU/container
    assert j1.serial_work == pytest.approx(3600.0 * 4)   # anchored at n_max
    assert wl[1].base_duration_s == 3600.0


def test_replay_alibaba_format():
    wl = replay_trace(ALIBABA_CSV, fmt="alibaba")
    assert [w.spec.app_id for w in wl] == ["j100/t1", "j102/t3"]
    a = wl[0].spec
    assert a.n_max == 4 and a.n_min == 1
    assert a.demand.values[0] == pytest.approx(2.0)      # plan_cpu 200 -> 2
    assert wl[0].base_duration_s == pytest.approx(3600.0)
    assert wl[1].spec.submit_time == pytest.approx(100.0)


def test_replay_generic_format_and_simulation():
    cfg = ReplayConfig()
    wl = replay_trace(GENERIC_CSV, fmt="generic", cfg=cfg)
    assert [w.spec.app_id for w in wl] == ["b", "a"]
    assert wl[0].spec.weight == 2 and wl[0].spec.n_min == 2
    # The replayed stream drives the SAME runtime as the generator's.
    cluster = _cluster(8, cap=(8, 1, 32))
    res = ClusterSimulator(_dorm(cluster, theta=(1.0, 1.0)), wl,
                           horizon_s=24 * 3600).run()
    assert len(res.durations()) == 2
    # granted full request -> finishes in ~the recorded duration
    assert res.durations()["b"] == pytest.approx(1200.0, rel=0.5)


def test_replay_rejects_unknown_format_and_bad_header():
    with pytest.raises(ValueError, match="unknown trace format"):
        replay_trace(GENERIC_CSV, fmt="nope")
    with pytest.raises(ValueError, match="misses columns"):
        replay_trace("a,b\n1,2\n", fmt="philly")


def test_replay_max_apps_truncates():
    wl = replay_trace(GENERIC_CSV, fmt="generic",
                      cfg=ReplayConfig(max_apps=1))
    assert [w.spec.app_id for w in wl] == ["b"]


# ---------------------------------------------------- live training bridge

@pytest.mark.slow
def test_runtime_drives_real_training_with_resize():
    """End-to-end: the shared runtime drives a DormMaster whose protocol
    trains REAL JAX jobs; an injected Resize forces a live checkpoint-based
    shrink without losing training progress."""
    jax = pytest.importorskip("jax")
    from repro.data import DataConfig
    from repro.models.config import ModelConfig
    from repro.training.elastic import (ElasticConfig, ElasticJaxProtocol,
                                        ElasticTrainer, RuntimeTrainingBridge)
    from repro.training.optimizer import OptimizerSpec

    tiny = ModelConfig("tiny", "dense", 2, 64, 2, 2, 128, 128, head_dim=32,
                       dtype="float32", attn_impl="ref")
    cluster = ClusterSpec.homogeneous(1, ResourceVector.of(8, 0, 32))
    proto = ElasticJaxProtocol(jax.devices(), devices_per_container=1,
                               oversubscribe=True)
    master = DormMaster(cluster, "greedy", OptimizerConfig(1.0, 1.0),
                        protocol=proto)

    def trainer(app_id):
        return ElasticTrainer(ElasticConfig(
            model=tiny,
            optimizer=OptimizerSpec(peak_lr=1e-3, warmup_steps=2,
                                    total_steps=50),
            data=DataConfig(vocab_size=128, seq_len=32, global_batch=4)),
            app_id)

    proto.register("j1", trainer("j1"))
    specs = [ApplicationSpec("j1", "repro", ResourceVector.of(2, 0, 8),
                             1, 4, 1, serial_work=40 * 3600.0)]
    wl = [WorkloadApp(spec=s, class_index=0, base_duration_s=s.serial_work)
          for s in specs]

    rt = ClusterRuntime(master, horizon_s=2 * 3600)
    bridge = RuntimeTrainingBridge(proto, steps_per_event=2)
    bridge.attach(rt.bus)
    rt.inject(Resize(1800.0, "j1", n_max=1))
    rt.run(wl)

    tr = proto.trainers["j1"]
    assert bridge.n_events >= 2                # arrival + resize
    assert tr.global_step >= 4                 # trained after each event
    assert master.containers_of("j1") == 1     # resize applied live
    assert tr.state is not None                # still resumable/running
