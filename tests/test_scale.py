"""Scale-path tests: the vectorized simulator is a bit-exact drop-in for the
seed event loop, sparse MILP assembly matches the dense reference, the auto
optimizer switches at the size threshold, and the trace generator / event
batching behave."""
import numpy as np
import pytest

from repro.core import (ApplicationSpec, AutoOptimizer, ClusterSimulator,
                        ClusterSpec, DormMaster, GreedyOptimizer,
                        MilpOptimizer, OptimizerConfig, RecordingProtocol,
                        ReferenceClusterSimulator, ResourceVector,
                        SCALE_CLASSES, StaticScheduler, TraceConfig,
                        generate_trace, generate_workload,
                        heterogeneous_cluster, paper_testbed,
                        resource_utilization, validate_allocation,
                        BASELINE_STATIC_CONTAINERS)


def _dorm(cluster, theta=(0.2, 0.2)):
    return DormMaster(cluster, "greedy", OptimizerConfig(*theta),
                      protocol=RecordingProtocol())


def _assert_same_result(a, b):
    assert len(a.samples) == len(b.samples)
    for sa, sb in zip(a.samples, b.samples):
        assert sa.t == pytest.approx(sb.t, abs=1e-9)
        assert sa.utilization == pytest.approx(sb.utilization, abs=1e-9)
        assert sa.fairness_loss == pytest.approx(sb.fairness_loss, abs=1e-9)
        assert sa.adjustment_overhead == sb.adjustment_overhead
        assert sa.running == sb.running
        assert sa.pending == sb.pending
    assert a.total_adjustments == b.total_adjustments
    assert a.completions.keys() == b.completions.keys()
    for app_id, ra in a.completions.items():
        rb = b.completions[app_id]
        assert ra.n_adjustments == rb.n_adjustments
        assert ra.remaining_work == pytest.approx(rb.remaining_work, abs=1e-9)
        assert ra.paused_until == pytest.approx(rb.paused_until, abs=1e-9)
        if ra.finished_at is None:
            assert rb.finished_at is None
        else:
            assert ra.finished_at == pytest.approx(rb.finished_at, abs=1e-9)


def test_vectorized_matches_reference_on_table_ii_dorm():
    """Golden: the Table-II workload under Dorm produces an identical
    MetricSample timeline in the vectorized and reference simulators."""
    wl = generate_workload(seed=0)
    cluster = paper_testbed()
    ref = ReferenceClusterSimulator(_dorm(cluster), wl,
                                    adjustment_cost_s=60.0,
                                    horizon_s=48 * 3600).run()
    vec = ClusterSimulator(_dorm(cluster), wl, adjustment_cost_s=60.0,
                           horizon_s=48 * 3600).run()
    _assert_same_result(ref, vec)


def test_vectorized_matches_reference_on_table_ii_static():
    """Golden, baseline scheduler path (exercises rate_multiplier too)."""
    wl = generate_workload(seed=1)[:25]
    cluster = paper_testbed()
    static = {w.spec.app_id: BASELINE_STATIC_CONTAINERS[w.class_index]
              for w in wl}
    ref = ReferenceClusterSimulator(StaticScheduler(cluster, static), wl,
                                    rate_multiplier=0.8,
                                    horizon_s=24 * 3600).run()
    vec = ClusterSimulator(StaticScheduler(cluster, static), wl,
                           rate_multiplier=0.8,
                           horizon_s=24 * 3600).run()
    _assert_same_result(ref, vec)


def _small_instance():
    cluster = ClusterSpec.homogeneous(4, ResourceVector.of(8, 1, 32))
    apps = [
        ApplicationSpec("a1", "MxNet", ResourceVector.of(2, 0, 8), 1, 8, 1),
        ApplicationSpec("a2", "TF", ResourceVector.of(2, 0, 6), 2, 8, 1),
        ApplicationSpec("a3", "Caffe", ResourceVector.of(1, 1, 8), 1, 4, 1),
    ]
    return cluster, apps


def test_sparse_dense_milp_same_objective():
    """The vectorized scipy.sparse assembly and the loop-built dense
    reference assembly describe the same MILP: equal objective values,
    with and without a previous allocation (adjustment constraints)."""
    cluster, apps = _small_instance()
    sparse_opt = MilpOptimizer(OptimizerConfig(0.2, 0.2, sparse=True))
    dense_opt = MilpOptimizer(OptimizerConfig(0.2, 0.2, sparse=False))

    a_s = sparse_opt.solve(apps, cluster, None)
    a_d = dense_opt.solve(apps, cluster, None)
    u_s = resource_utilization(a_s, apps, cluster)
    u_d = resource_utilization(a_d, apps, cluster)
    assert u_s == pytest.approx(u_d, abs=1e-6)

    # With a previous allocation + one new app: exercises Eqs 13-14/16 rows.
    apps4 = apps + [ApplicationSpec("a4", "MxNet",
                                    ResourceVector.of(2, 0, 8), 1, 8, 1)]
    b_s = sparse_opt.solve(apps4, cluster, a_s)
    b_d = dense_opt.solve(apps4, cluster, a_s)
    assert (b_s is None) == (b_d is None)
    if b_s is not None:
        validate_allocation(b_s, apps4, cluster)
        assert resource_utilization(b_s, apps4, cluster) == pytest.approx(
            resource_utilization(b_d, apps4, cluster), abs=1e-6)


def test_auto_optimizer_switches_at_threshold():
    cluster, apps = _small_instance()
    auto = AutoOptimizer(OptimizerConfig(0.2, 0.2, auto_switch_vars=100))
    assert isinstance(auto.select(apps, cluster), MilpOptimizer)
    big = ClusterSpec.homogeneous(64, ResourceVector.of(8, 1, 32))
    assert isinstance(auto.select(apps, big), GreedyOptimizer)  # 3*64 > 100
    alloc = auto.solve(apps, big, None)
    assert alloc is not None
    validate_allocation(alloc, apps, big)


def test_warm_start_keeps_small_instances_exact():
    """warm_start adds a cutoff plane from the greedy incumbent; on a small
    feasible instance the MILP optimum must be unchanged."""
    cluster, apps = _small_instance()
    cold = MilpOptimizer(OptimizerConfig(0.2, 0.2)).solve(apps, cluster, None)
    warm_opt = MilpOptimizer(OptimizerConfig(0.2, 0.2, warm_start=True))
    warm = warm_opt.solve(apps, cluster, cold)
    assert warm is not None
    validate_allocation(warm, apps, cluster)
    assert resource_utilization(warm, apps, cluster) >= \
        resource_utilization(cold, apps, cluster) - 1e-6


def test_trace_generator_shape_and_arrivals():
    cfg = TraceConfig(n_apps=200, seed=7)
    wl = generate_trace(cfg)
    assert len(wl) == 200
    times = [w.spec.submit_time for w in wl]
    assert times == sorted(times)
    assert len({w.spec.app_id for w in wl}) == 200
    kinds = {SCALE_CLASSES[w.class_index][6] for w in wl}
    assert kinds == {"train", "serve"}      # both job populations present
    # Bursts exist: some serving arrivals share a timestamp.
    assert len(set(times)) < len(times)
    for w in wl:
        _, _, demand, weight, n_max, n_min, _ = SCALE_CLASSES[w.class_index]
        assert w.spec.n_min == n_min and w.spec.n_max == n_max
        assert w.spec.serial_work > 0


def test_heterogeneous_cluster_mixes_flavors():
    cluster = heterogeneous_cluster(100, seed=3)
    assert cluster.b == 100
    caps = {tuple(s.capacity.values) for s in cluster.slaves}
    assert len(caps) == 3                   # all three flavors present
    assert cluster.total_capacity()[1] > 0  # some GPUs in the mix


def test_event_batching_coalesces_bursts():
    """With a batch window, a burst of coincident arrivals is admitted in
    one scheduler pass: fewer reallocation events, same completions."""
    cfg = TraceConfig(n_apps=60, seed=5, mean_interarrival_s=300.0,
                      serving_fraction=0.8, burst_prob=0.5)
    wl = generate_trace(cfg)
    cluster = heterogeneous_cluster(40, seed=0)
    one_by_one = ClusterSimulator(_dorm(cluster), wl,
                                  horizon_s=24 * 3600).run()
    batched = ClusterSimulator(_dorm(cluster), wl, horizon_s=24 * 3600,
                               batch_window_s=120.0).run()
    assert len(batched.samples) < len(one_by_one.samples)
    assert len(batched.durations()) == len(one_by_one.durations())
