"""Property suite for the sharded control plane (PR 10).

Random interleavings of Arrival / Completion / Resize / chaos / Migrate
events driven through `ShardedControlPlane`. Invariants, after every
single event:

  * a 1-shard plane is BIT-EXACT vs a bare `DormMaster` event-for-event
    (every result field, master-level and runtime-level, absorber and
    chaos included) -- sharding with K=1 is free;
  * no app is ever owned by two shards: the per-shard specs maps stay
    pairwise disjoint and their union is exactly the admitted set;
  * migration never loses work beyond Eq-4: the migrant's spec arrives
    on the destination unchanged, a running migrant is charged exactly
    one forced adjustment, and the app is placed-or-pending afterwards
    -- never vanished, never half-placed;
  * per-shard capacity is never exceeded under chaos floods (each
    shard's effective capacity honors the same invariant the single
    master does).

Runs under hypothesis when available; falls back to a seeded-random
sweep of the same checks otherwise."""
import dataclasses

import numpy as np
import pytest

from repro.core import (AbsorberConfig, ApplicationSpec, ChaosConfig,
                        ClusterRuntime, ClusterSpec, Coordinator, DormMaster,
                        OptimizerConfig, Reallocated, RecordingProtocol,
                        ResourceVector, ShardConfig, ShardedControlPlane,
                        TraceConfig, cross_shard_certificate, generate_trace,
                        partition_cluster)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

THETAS = ((0.2, 0.2), (1.0, 1.0), (0.1, 0.3))


# ---------------------------------------------------------------------------
# random event scripts
# ---------------------------------------------------------------------------

def _gen_ops(rng, n_shards):
    """Random shard-stressing event script: (cluster, theta, ops)."""
    b = n_shards * int(rng.integers(2, 5))     # b % K == 0: proportional
    cap = ResourceVector.of(int(rng.integers(6, 14)),
                            int(rng.integers(1, 3)),
                            int(rng.integers(16, 49)))
    cluster = ClusterSpec.homogeneous(b, cap)
    theta = THETAS[int(rng.integers(len(THETAS)))]

    ops = []
    alive = []
    down = set()
    next_id = 0
    for _ in range(int(rng.integers(10, 21))):
        choices = ["arrive", "arrive", "fail", "degrade"]
        if alive:
            choices += ["complete", "resize"]
            if n_shards > 1:
                choices += ["migrate", "migrate"]
        if down:
            choices += ["restore", "restore"]
        op = choices[int(rng.integers(len(choices)))]
        if op == "arrive":
            n_min = int(rng.integers(1, 3))
            n_max = n_min + int(rng.integers(0, 6))
            spec = ApplicationSpec(
                f"a{next_id}", "x",
                ResourceVector.of(int(rng.integers(1, 4)),
                                  int(rng.integers(0, 2)),
                                  int(rng.integers(1, 13))),
                int(rng.integers(1, 4)), n_max, n_min)
            next_id += 1
            alive.append(spec.app_id)
            ops.append(("arrive", spec))
        elif op == "complete":
            app = alive.pop(int(rng.integers(len(alive))))
            ops.append(("complete", app))
        elif op == "resize":
            app = alive[int(rng.integers(len(alive)))]
            lo = int(rng.integers(1, 4))
            ops.append(("resize", app, lo, lo + int(rng.integers(0, 7))))
        elif op == "migrate":
            app = alive[int(rng.integers(len(alive)))]
            ops.append(("migrate", app, int(rng.integers(n_shards))))
        elif op == "fail":
            j = int(rng.integers(b))
            down.add(j)
            kind = "fail" if rng.random() < 0.7 else "drain"
            ops.append((kind, f"slave-{j}"))
        elif op == "degrade":
            j = int(rng.integers(b))
            down.add(j)
            f = float(rng.choice([0.25, 0.5, 0.75]))
            ops.append(("degrade", f"slave-{j}", f))
        else:  # restore
            j = down.pop() if rng.random() < 0.8 else int(rng.integers(b))
            ops.append(("restore", f"slave-{j}"))
    return cluster, theta, ops


def _apply(policy, op):
    kind = op[0]
    if kind == "arrive":
        return policy.on_arrival((op[1],))
    if kind == "complete":
        return policy.on_completion(op[1])
    if kind == "resize":
        return policy.on_resize(op[1], op[2], op[3])
    if kind == "migrate":
        return policy.migrate(op[1], op[2])
    if kind == "fail":
        return policy.on_slave_failed(op[1])
    if kind == "drain":
        return policy.on_slave_drained(op[1])
    if kind == "degrade":
        return policy.on_slave_degraded(op[1], op[2])
    return policy.on_slave_restored(op[1])


def _plane(cluster, theta, n_shards):
    cfg = OptimizerConfig(*theta)
    return ShardedControlPlane(cluster, ShardConfig(n_shards=n_shards),
                               optimizer_kind="greedy", optimizer_cfg=cfg)


# ---------------------------------------------------------------------------
# invariants
# ---------------------------------------------------------------------------

def _check_shard_invariants(plane, res):
    """Per-shard capacity/bounds + global single-ownership, from the
    masters' own post-event view."""
    seen = {}
    for sh in plane.shards:
        m = sh.master
        cap = m.cluster.capacity_matrix()
        used = np.zeros_like(cap, dtype=np.float64)
        placed = set()
        for app_id in list(m.partitions):
            spec = m.specs[app_id]
            row = m.state.placement(app_id) if m.state is not None \
                else m._placements[app_id]
            count = int(row.sum())
            placed.add(app_id)
            assert spec.n_min <= count <= spec.n_max, \
                f"shard {sh.index} {app_id}: {count} outside bounds"
            used += row[:, None] * spec.demand.as_array()[None, :]
        assert np.all(used <= cap + 1e-6), \
            f"shard {sh.index}: effective capacity exceeded"
        assert placed | set(m.pending) == set(m.specs), sh.index
        for app_id in m.specs:
            assert app_id not in seen, \
                f"{app_id} owned by shards {seen[app_id]} and {sh.index}"
            seen[app_id] = sh.index
    # The owner map is exactly the union of the shards' admitted sets.
    assert dict(plane.owner) == seen
    if res is not None:
        assert set(res.forced_adjusted_app_ids) <= set(res.adjusted_app_ids)


def _check_plane_storm(seed, n_shards):
    rng = np.random.default_rng(seed)
    cluster, theta, ops = _gen_ops(rng, n_shards)
    plane = _plane(cluster, theta, n_shards)
    for op in ops:
        if op[0] == "migrate":
            src = plane.owner.get(op[1])
            src_spec = (plane.shards[src].master.specs.get(op[1])
                        if src is not None else None)
            was_running = plane.containers_of(op[1]) > 0
            res = _apply(plane, op)
            _check_shard_invariants(plane, res)
            if res is None:
                # Unknown app or src == dst: nothing may have moved.
                assert plane.owner.get(op[1]) == src
                continue
            # -- migration loses no work beyond Eq-4:
            dst = op[2]
            assert res.migrated_app_ids == (op[1],)
            assert plane.owner[op[1]] == dst
            # the spec crossed shards unchanged (same bounds, demand, work)
            assert plane.shards[dst].master.specs[op[1]] == src_spec
            if was_running:
                # exactly one forced Eq-4 adjustment, never a fresh start
                assert op[1] in res.forced_adjusted_app_ids
                assert op[1] in res.adjusted_app_ids
                assert op[1] not in res.started_app_ids
            # placed within bounds on dst, or pending there -- never gone
            dst_m = plane.shards[dst].master
            c = dst_m.containers_of(op[1])
            if c > 0:
                assert src_spec.n_min <= c <= src_spec.n_max
            else:
                assert op[1] in dst_m.pending
            assert res.changed_counts is not None \
                and op[1] in res.changed_counts
        else:
            res = _apply(plane, op)
            _check_shard_invariants(plane, res)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2 ** 32 - 1), st.integers(2, 4))
    @settings(max_examples=60, deadline=None)
    def test_shard_storms_hold_invariants(seed, n_shards):
        _check_plane_storm(seed, n_shards)
else:
    @pytest.mark.parametrize("chunk", range(6))
    def test_shard_storms_hold_invariants(chunk):
        # Seeded fallback: 6 chunks x 10 seeds = 60 examples.
        for k in range(10):
            seed = chunk * 10 + k
            _check_plane_storm(seed, 2 + seed % 3)


# ---------------------------------------------------------------------------
# 1-shard bit-exactness (master-level)
# ---------------------------------------------------------------------------

def _check_one_shard_bit_exact(seed):
    rng = np.random.default_rng(seed)
    cluster, theta, ops = _gen_ops(rng, 1)
    plane = _plane(cluster, theta, 1)
    cfg = OptimizerConfig(*theta)
    master = DormMaster(cluster, "greedy", cfg,
                        protocol=RecordingProtocol())
    for op in ops:
        res_p = _apply(plane, op)
        res_m = _apply(master, op)
        assert (res_p is None) == (res_m is None), op
        if res_m is None:
            continue
        assert res_p.allocation.app_ids == res_m.allocation.app_ids, op
        np.testing.assert_array_equal(res_p.allocation.x, res_m.allocation.x,
                                      err_msg=str(op))
        assert res_p.adjusted_app_ids == res_m.adjusted_app_ids, op
        assert res_p.started_app_ids == res_m.started_app_ids, op
        assert res_p.pending_app_ids == res_m.pending_app_ids, op
        assert res_p.forced_adjusted_app_ids == \
            res_m.forced_adjusted_app_ids, op
        assert res_p.displaced_app_ids == res_m.displaced_app_ids, op
        assert res_p.parked_app_ids == res_m.parked_app_ids, op
        assert res_p.changed_counts == res_m.changed_counts, op
        assert res_p.utilization == res_m.utilization, op
        assert res_p.fairness_loss == res_m.fairness_loss, op
        assert res_p.adjustment_overhead == res_m.adjustment_overhead, op
        assert res_p.goodput == res_m.goodput, op


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2 ** 32 - 1))
    @settings(max_examples=60, deadline=None)
    def test_one_shard_plane_bit_exact_vs_master(seed):
        _check_one_shard_bit_exact(seed)
else:
    @pytest.mark.parametrize("chunk", range(6))
    def test_one_shard_plane_bit_exact_vs_master(chunk):
        for k in range(10):
            _check_one_shard_bit_exact(chunk * 10 + k)


# ---------------------------------------------------------------------------
# 1-shard bit-exactness (runtime-level, absorber + chaos)
# ---------------------------------------------------------------------------

def _run(policy_factory, cluster, wl, chaos, absorber=None):
    rt = ClusterRuntime(policy_factory(cluster), horizon_s=12 * 3600.0,
                        chaos=chaos, absorber=absorber)
    allocs = []
    rt.bus.subscribe(Reallocated,
                     lambda e: allocs.append((e.t,
                                              e.result.allocation.app_ids,
                                              e.result.allocation.x.copy())))
    res = rt.run(wl)
    return res, allocs, rt


def _assert_timelines_equal(a, b, ctx=""):
    (res_a, al_a, _), (res_b, al_b, _) = a, b
    assert len(al_a) == len(al_b), ctx
    for (t1, ids1, x1), (t2, ids2, x2) in zip(al_a, al_b):
        assert t1 == t2 and ids1 == ids2, ctx
        np.testing.assert_array_equal(x1, x2, err_msg=ctx)
    assert res_a.durations() == res_b.durations(), ctx
    assert res_a.total_forced_adjustments == \
        res_b.total_forced_adjustments, ctx
    assert len(res_a.samples) == len(res_b.samples), ctx
    for sa, sb in zip(res_a.samples, res_b.samples):
        assert sa.t == sb.t and sa.running == sb.running, ctx
        assert sa.pending == sb.pending, ctx
        assert sa.adjustment_overhead == sb.adjustment_overhead, ctx
        assert sa.forced_adjustments == sb.forced_adjustments, ctx
        assert sa.utilization == pytest.approx(sb.utilization, abs=0.0)
        assert sa.fairness_loss == pytest.approx(sb.fairness_loss, abs=0.0)


def _check_one_shard_runtime(seed):
    rng = np.random.default_rng(seed)
    cluster = ClusterSpec.homogeneous(
        int(rng.integers(6, 12)), ResourceVector.of(8, 2, 32))
    wl = generate_trace(TraceConfig(n_apps=int(rng.integers(8, 14)),
                                    seed=seed, mean_interarrival_s=400.0))
    chaos = ChaosConfig(seed=int(seed) % 1009, crashes_per_day=20.0,
                        rack_size=2, crash_restore_s=1800.0)
    cfg = OptimizerConfig(0.2, 0.2)

    def master(cl):
        return DormMaster(cl, "greedy", cfg, protocol=RecordingProtocol())

    def plane(cl):
        return ShardedControlPlane(cl, ShardConfig(n_shards=1),
                                   optimizer_kind="greedy",
                                   optimizer_cfg=cfg)

    for absorber in (None, AbsorberConfig()):
        ref = _run(master, cluster, wl, chaos, absorber=absorber)
        got = _run(plane, cluster, wl, chaos, absorber=absorber)
        _assert_timelines_equal(ref, got,
                                f"seed={seed} absorber={absorber}")


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2 ** 32 - 1))
    @settings(max_examples=6, deadline=None)
    def test_one_shard_runtime_timeline_bit_exact(seed):
        _check_one_shard_runtime(seed)
else:
    @pytest.mark.parametrize("seed", range(4))
    def test_one_shard_runtime_timeline_bit_exact(seed):
        _check_one_shard_runtime(seed)


# ---------------------------------------------------------------------------
# deterministic units: partitioning, coordinator, certificate
# ---------------------------------------------------------------------------

def test_partition_cluster_round_robin_proportional():
    cluster = ClusterSpec.homogeneous(12, ResourceVector.of(8, 2, 32))
    shards = partition_cluster(cluster, 4)
    assert [s.b for s in shards] == [3, 3, 3, 3]
    # shard s owns global slaves s, s+4, s+8 -- ids preserved verbatim
    assert [s.slave_id for s in shards[1].slaves] == \
        ["slave-1", "slave-5", "slave-9"]
    for s in shards:
        np.testing.assert_allclose(s.total_capacity(),
                                   cluster.total_capacity() / 4)
    with pytest.raises(ValueError):
        partition_cluster(cluster, 13)


def _spec(i, n_min=1, n_max=3):
    return ApplicationSpec(f"m{i}", "x", ResourceVector.of(2, 1, 8),
                           1, n_max, n_min)


def test_coordinator_relieves_imbalance():
    """Kill every app on one shard; the next rebalance must move load
    toward the emptied shard (the CI smoke's migration >= 1 guarantee)."""
    cluster = ClusterSpec.homogeneous(8, ResourceVector.of(8, 2, 32))
    plane = ShardedControlPlane(
        cluster, ShardConfig(n_shards=2, rebalance_interval_s=600.0,
                             imbalance_threshold=0.2),
        optimizer_kind="greedy")
    plane.on_arrival(tuple(_spec(i) for i in range(8)))
    for app_id, owner in list(plane.owner.items()):
        if owner == 1:
            plane.on_completion(app_id)
    assert all(s == 0 for s in plane.owner.values())
    coord = Coordinator(plane)
    moves = coord.rebalance(t=1000.0)
    assert len(moves) >= 1
    assert plane.migration_count == len(moves)
    assert all(mv.src_shard == 0 and mv.dst_shard == 1 for mv in moves)
    _check_shard_invariants(plane, None)
    # a second rebalance inside the interval is gated off entirely
    assert coord.rebalance(t=1100.0) == []
    assert coord.migrations == moves


def test_cross_shard_certificate_small():
    cluster = ClusterSpec.homogeneous(8, ResourceVector.of(8, 2, 32))
    plane = ShardedControlPlane(cluster, ShardConfig(n_shards=2),
                                optimizer_kind="greedy")
    plane.on_arrival(tuple(_spec(i, n_min=1, n_max=4) for i in range(6)))
    cert = cross_shard_certificate(plane)
    assert cert["global_bound"] is not None
    assert cert["sharded_bound"] is not None      # proportional shards
    assert cert["cross_shard_gap"] is not None
    assert 0.0 <= cert["cross_shard_gap"] < 1.0
    # the sharded achieved value can never beat the certified global bound
    assert cert["sharded_objective"] <= cert["global_bound"] + 1e-6
    assert cert["partition_gap"] <= cert["cross_shard_gap"] + 1e-6
