"""Hypothesis property tests on the cluster simulator's physical invariants:
work conservation, capacity safety over time, fairness budgets under random
workloads -- the simulation-level counterpart of tests/test_properties.py."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis is not in the baked image (no pip install allowed); "
           "these property tests run wherever it is available")
from hypothesis import given, settings, strategies as st

from repro.core import (ApplicationSpec, ClusterSimulator, ClusterSpec,
                        DormMaster, OptimizerConfig, RecordingProtocol,
                        ResourceVector, StaticScheduler, WorkloadApp,
                        fairness_budget)


@st.composite
def small_workload(draw):
    n = draw(st.integers(2, 8))
    apps = []
    t = 0.0
    for i in range(n):
        t += draw(st.floats(60, 3600))
        dur = draw(st.floats(600, 6 * 3600))
        n_max = draw(st.integers(1, 6))
        spec = ApplicationSpec(
            f"w{i}", "x",
            ResourceVector.of(draw(st.integers(1, 3)), 0,
                              draw(st.integers(2, 8))),
            weight=draw(st.integers(1, 3)), n_max=n_max, n_min=1,
            serial_work=dur * min(2, n_max), submit_time=t)
        apps.append(WorkloadApp(spec=spec, class_index=0,
                                base_duration_s=dur))
    return apps


def _cluster():
    return ClusterSpec.homogeneous(4, ResourceVector.of(8, 0, 32))


@given(small_workload(), st.sampled_from([0.1, 0.3]))
@settings(max_examples=15, deadline=None)
def test_dorm_simulation_invariants(wl, theta):
    cluster = _cluster()
    master = DormMaster(cluster, "greedy",
                        OptimizerConfig(theta, theta),
                        protocol=RecordingProtocol())
    sim = ClusterSimulator(master, wl, adjustment_cost_s=30.0,
                           horizon_s=48 * 3600)
    res = sim.run()

    # capacity safety at every event: utilization never exceeds m
    for s in res.samples:
        assert s.utilization <= cluster.m + 1e-6
        assert s.fairness_loss <= fairness_budget(
            OptimizerConfig(theta, theta), cluster.m) + 1e-6

    # work conservation: completed apps consumed exactly their serial work
    for app_id, rt in res.completions.items():
        if rt.finished_at is not None:
            assert rt.remaining_work <= 1e-6
            # duration >= serial_work / n_max (can't run faster than max scale)
            spec = rt.app.spec
            min_dur = spec.serial_work / spec.n_max
            assert rt.finished_at - rt.submitted_at >= min_dur - 1e-6

    # adjustment pauses accounted: every adjusted app was paused
    for app_id, rt in res.completions.items():
        if rt.n_adjustments > 0 and rt.finished_at is not None:
            spec = rt.app.spec
            assert rt.finished_at - rt.submitted_at >= \
                spec.serial_work / spec.n_max - 1e-6


@given(small_workload())
@settings(max_examples=10, deadline=None)
def test_static_never_adjusts_and_dorm_dominates_utilization(wl):
    cluster = _cluster()
    static = {w.spec.app_id: 2 for w in wl}
    base = ClusterSimulator(StaticScheduler(cluster, static), wl,
                            horizon_s=48 * 3600).run()
    assert base.total_adjustments == 0
    master = DormMaster(cluster, "greedy", OptimizerConfig(0.3, 0.3),
                        protocol=RecordingProtocol())
    dorm = ClusterSimulator(master, wl, adjustment_cost_s=30.0,
                            horizon_s=48 * 3600).run()
    # Dorm's whole-run utilization is never materially below static's
    u_d = dorm.time_averaged_utilization()
    u_b = base.time_averaged_utilization()
    assert u_d >= u_b - 0.15
