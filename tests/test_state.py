"""SoA allocation engine (repro.core.state) tests: incremental bookkeeping
matches brute force, lazy object materialization is consistent, the batched
best-fit placement equals the sequential reference, and the SoA master is
bit-exact with the PR-2 dict-of-objects engine across whole event streams."""
import numpy as np
import pytest

from repro.core import (ApplicationSpec, ClusterSimulator, ClusterSpec,
                        ClusterState, DormMaster, OptimizerConfig,
                        Reallocated, RecordingProtocol, ResourceVector,
                        TraceConfig, generate_trace, heterogeneous_cluster)
from repro.core.optimizer import _best_fit_place, _best_fit_place_batch


def _app(i, cpus=2, gpus=0, ram=8, w=1, nmax=8, nmin=1):
    return ApplicationSpec(f"app{i}", "x",
                           ResourceVector.of(cpus, gpus, ram), w, nmax, nmin)


def _cluster(n=4, cap=(16, 2, 64)):
    return ClusterSpec.homogeneous(n, ResourceVector.of(*cap))


# ---------------------------------------------------------------- bookkeeping

def test_state_free_capacity_matches_brute_force():
    rng = np.random.default_rng(0)
    cluster = _cluster(6)
    state = ClusterState(cluster)
    apps = [_app(i, cpus=int(rng.integers(1, 4)), ram=int(rng.integers(2, 9)))
            for i in range(8)]
    for a in apps:
        state.admit(a)
    live = {}
    for step in range(60):
        a = apps[int(rng.integers(len(apps)))]
        if a.app_id in live and rng.random() < 0.4:
            state.clear(a.app_id)
            del live[a.app_id]
        else:
            row = rng.integers(0, 2, size=cluster.b)
            state.place(a.app_id, row)
            live[a.app_id] = row
        # brute force: free = cap - sum_i x_i ⊗ d_i
        used = np.zeros((cluster.b, cluster.m))
        for app_id, row in live.items():
            d = state.demand[state.row_of[app_id]]
            used += row[:, None] * d[None, :]
        np.testing.assert_allclose(state.free, state.cap - used)
        for app_id, row in live.items():
            assert state.containers_of(app_id) == int(row.sum())
            np.testing.assert_array_equal(state.placement(app_id), row)


def test_state_row_recycling_and_aggregate_nmax():
    cluster = _cluster(2)
    state = ClusterState(cluster, capacity_hint=2)
    a, b, c = _app(1, nmax=4), _app(2, nmax=2), _app(3, nmax=8)
    state.admit(a)
    state.admit(b)
    np.testing.assert_allclose(
        state.nmax_demand, 4 * a.demand.as_array() + 2 * b.demand.as_array())
    state.place(a.app_id, np.array([1, 1]))
    state.forget(a.app_id)                  # releases row AND capacity
    np.testing.assert_allclose(state.free, state.cap)
    state.admit(c)                          # recycles a's row
    np.testing.assert_allclose(
        state.nmax_demand, 2 * b.demand.as_array() + 8 * c.demand.as_array())
    assert state.saturates_at_nmax() == (
        bool(np.all(state.nmax_demand <= state.total_cap + 1e-9)))
    # growth past the initial capacity hint keeps data intact
    for i in range(10, 30):
        state.admit(_app(i))
    assert state.containers_of(b.app_id) == 0
    state.place(b.app_id, np.array([2, 0]))
    assert state.containers_of(b.app_id) == 2


def test_allocation_gather_correct_when_placed_order_diverges():
    """Regression (code review): placement order can diverge from admission
    order in the MIDDLE while first and last app coincide (adjust a middle
    app, then place a newly admitted one). The row gather must still pair
    every app id with ITS row, not the admission-order cache."""
    cluster = _cluster(4)
    state = ClusterState(cluster)
    rows = {}
    for i, app in enumerate([_app(1), _app(2), _app(3)]):
        state.admit(app)
        row = np.zeros(cluster.b, np.int64)
        row[i] = i + 1
        state.place(app.app_id, row)
        rows[app.app_id] = row
    # adjust the MIDDLE app: teardown + re-place moves it to the end of
    # the placed order (admission order unchanged)
    state.clear("app2")
    new2 = np.zeros(cluster.b, np.int64)
    new2[3] = 7
    state.place("app2", new2)
    rows["app2"] = new2
    state.admit(_app(4))
    new4 = np.zeros(cluster.b, np.int64)
    new4[0] = 5
    state.place("app4", new4)
    rows["app4"] = new4
    assert state.placed_ids() == ("app1", "app3", "app2", "app4")
    alloc = state.allocation()
    for i, a in enumerate(alloc.app_ids):
        np.testing.assert_array_equal(alloc.x[i], rows[a])
    # admission-order query still hits the cache and stays correct
    alloc2 = state.allocation(("app1", "app2", "app3", "app4"))
    for i, a in enumerate(alloc2.app_ids):
        np.testing.assert_array_equal(alloc2.x[i], rows[a])


def test_state_epoch_bumps_only_when_capacity_returns():
    cluster = _cluster(2)
    state = ClusterState(cluster)
    a = _app(1)
    state.admit(a)
    e0 = state.epoch
    state.place(a.app_id, np.array([2, 0]))     # pure growth: no bump
    assert state.epoch == e0
    state.place(a.app_id, np.array([3, 0]))
    assert state.epoch == e0
    state.place(a.app_id, np.array([1, 2]))     # slave 0 regained capacity
    assert state.epoch > e0
    e1 = state.epoch
    state.clear(a.app_id)
    assert state.epoch > e1


def test_update_spec_rebounds_and_rejects_demand_change():
    cluster = _cluster(2)
    state = ClusterState(cluster)
    a = _app(1, nmax=4)
    state.admit(a)
    state.update_spec(a.with_bounds(n_max=8))
    np.testing.assert_allclose(state.nmax_demand, 8 * a.demand.as_array())
    import dataclasses
    changed = dataclasses.replace(a, demand=ResourceVector.of(9, 9, 9))
    with pytest.raises(ValueError):
        state.update_spec(changed)


# ------------------------------------------------------- lazy materialization

def test_lazy_views_materialize_on_demand_only():
    m = DormMaster(_cluster(), "greedy", OptimizerConfig(0.2, 0.2),
                   protocol=RecordingProtocol())
    m.submit(_app(1))
    state = m.state
    assert state is not None
    # membership and iteration must NOT build objects
    assert "app1" in m.partitions
    assert list(m.partitions) == ["app1"]
    assert not state._parts
    n = m.containers_of("app1")
    assert n >= 1
    # materialization on access: one executor/scheduler per container,
    # containers match the placement row per slave
    assert len(m.executors["app1"]) == n
    assert len(m.schedulers["app1"]) == n
    part = m.partitions["app1"]
    assert part.n_containers == n
    np.testing.assert_array_equal(part.placement(m.slave_ids),
                                  state.placement("app1"))
    # slave views agree with the state (and with each other)
    used = sum(np.asarray(m.slaves[s].used()) for s in m.slave_ids)
    assert used.sum() > 0
    total_by_slave = sum(len(m.slaves[s].containers_of("app1"))
                         for s in m.slave_ids)
    assert total_by_slave == n
    # a placement change invalidates the cached objects
    m.submit(_app(2, nmax=32))
    if "app1" in [a for a in m.partitions]:
        _ = m.partitions["app1"]            # re-materializes cleanly
    m.complete("app1")
    m.complete("app2")
    assert sum(np.asarray(m.slaves[s].used()).sum()
               for s in m.slave_ids) == 0


# ------------------------------------------------ batched best-fit placement

def test_batched_best_fit_matches_sequential_reference():
    rng = np.random.default_rng(1)
    for trial in range(200):
        b = int(rng.integers(1, 12))
        mdim = 3
        cap = rng.integers(4, 40, size=(b, mdim)).astype(np.float64)
        free1 = cap - rng.integers(0, 4, size=(b, mdim))
        free1 = np.maximum(free1, 0.0)
        free2 = free1.copy()
        n = int(rng.integers(1, 5))
        d = rng.integers(0, 5, size=(n, mdim)).astype(np.float64)
        inv_cap = 1.0 / np.maximum(cap, 1e-9)
        x1 = np.zeros((n, b), np.int64)
        x2 = np.zeros((n, b), np.int64)
        for i in range(n):
            limit = int(rng.integers(1, 20))
            _best_fit_place(x1, free1, d, inv_cap, i, limit)
            _best_fit_place_batch(x2, free2, d, inv_cap, i, limit)
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_allclose(free1, free2)


# ------------------------------------------- engine-level stream bit-exactness

def _run_engine(soa, cluster, wl, incremental=True):
    cfg = OptimizerConfig(0.2, 0.2, incremental=incremental, soa=soa)
    m = DormMaster(cluster, "greedy", cfg, protocol=RecordingProtocol())
    allocs = []
    sim = ClusterSimulator(m, wl, horizon_s=24 * 3600.0)
    sim.runtime.bus.subscribe(
        Reallocated,
        lambda e: allocs.append((e.t, e.result.allocation.app_ids,
                                 e.result.allocation.x.copy(),
                                 e.result.adjusted_app_ids,
                                 e.result.started_app_ids)))
    res = sim.run()
    return res, allocs


@pytest.mark.parametrize("n_slaves,n_apps,seed,inter", [
    (60, 60, 4, 600.0),      # abundant: delta path dominates
    (10, 40, 7, 120.0),      # saturated: full solves + infeasible episodes
])
def test_soa_engine_bit_exact_with_object_engine(n_slaves, n_apps, seed,
                                                 inter):
    """The SoA engine is a pure optimization: allocation timelines, event
    times, adjusted/started sets, durations and (to float tolerance; the
    engines sum Eq-2 in different float orders) metric samples all match
    the PR-2 dict-of-objects engine."""
    cluster = heterogeneous_cluster(n_slaves, seed=1)
    wl = generate_trace(TraceConfig(n_apps=n_apps, seed=seed,
                                    mean_interarrival_s=inter))
    res_s, al_s = _run_engine(True, cluster, wl)
    res_l, al_l = _run_engine(False, cluster, wl)
    assert len(al_s) == len(al_l)
    for (ts, ids_s, x_s, adj_s, st_s), (tl, ids_l, x_l, adj_l, st_l) in zip(
            al_s, al_l):
        assert ts == tl
        assert ids_s == ids_l
        np.testing.assert_array_equal(x_s, x_l)
        assert adj_s == adj_l
        assert st_s == st_l
    assert res_s.durations() == res_l.durations()
    for sa, sb in zip(res_s.samples, res_l.samples):
        assert sa.t == sb.t
        assert sa.running == sb.running and sa.pending == sb.pending
        assert sa.adjustment_overhead == sb.adjustment_overhead
        assert sa.utilization == pytest.approx(sb.utilization, abs=1e-9)
        assert sa.fairness_loss == pytest.approx(sb.fairness_loss, abs=1e-9)


# --------------------------------------------- incremental runtime slot sync

def test_master_reports_changed_counts_contract():
    """`ReallocationResult.changed_counts` lists exactly the started +
    adjusted apps with their new counts (the runtime's incremental
    slot-sync contract); an infeasible event reports an empty dict."""
    cluster = ClusterSpec.homogeneous(1, ResourceVector.of(4, 0, 16))
    m = DormMaster(cluster, "greedy", OptimizerConfig(0.2, 0.2),
                   protocol=RecordingProtocol())
    res = m.submit(ApplicationSpec("a", "x", ResourceVector.of(2, 0, 8),
                                   1, 4, 1))
    assert set(res.changed_counts) == set(res.started_app_ids)
    assert res.changed_counts["a"] == m.containers_of("a")
    # infeasible arrival: nothing changed
    res2 = m.submit(ApplicationSpec("b", "x", ResourceVector.of(4, 0, 16),
                                    1, 1, 1))
    assert "b" in res2.pending_app_ids
    assert res2.changed_counts == {}
    res3 = m.complete("a")
    assert set(res3.changed_counts) == \
        set(res3.started_app_ids) | set(res3.adjusted_app_ids)


# -------------------------------------------------------- phase breakdown

def test_phase_breakdown_and_telemetry_row():
    from repro.core import MetricsLogger
    m = DormMaster(_cluster(), "greedy", OptimizerConfig(0.2, 0.2),
                   protocol=RecordingProtocol())
    m.submit(_app(1))
    m.submit(_app(2))
    m.complete("app1")
    phases = m.phase_breakdown()
    assert set(phases) == {"drf_refill", "colgen_pricing", "backend_compile",
                           "solve", "enforce", "metrics", "absorb"}
    assert all(v >= 0.0 for v in phases.values())
    assert phases["solve"] + phases["drf_refill"] > 0.0
    logger = MetricsLogger()
    logger.log_phase_breakdown(phases, t=123.0, engine="soa")
    row = logger.of_kind("phase")[0]
    assert row["t"] == 123.0 and row["engine"] == "soa"
    assert row["solve"] == phases["solve"]
    assert "phase_breakdown" in logger.summary() or not logger.of_kind("sample")
