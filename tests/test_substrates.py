"""Data pipeline, checkpointing, optimizer, serving, and elastic-trainer
substrate tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, load_meta, save_checkpoint
from repro.data import DataConfig, TokenPipeline
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.serving import generate
from repro.training.elastic import ElasticConfig, ElasticTrainer
from repro.training.optimizer import (OptimizerSpec, apply_updates,
                                      global_norm, init_opt_state,
                                      warmup_cosine_schedule)
from repro.training.train_loop import init_train_state, make_train_step

TINY = ModelConfig("tiny", "dense", 2, 64, 2, 2, 128, 128, head_dim=32,
                   dtype="float32", attn_impl="ref")


# ----------------------------------------------------------------- data

def test_pipeline_deterministic_across_shardings():
    """The same global step yields identical global batches no matter the
    shard layout -- the property Dorm's resize depends on."""
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=8, seed=3)
    whole = TokenPipeline(cfg, num_shards=1, shard_id=0).next_batch()
    parts = [TokenPipeline(cfg, num_shards=4, shard_id=i).next_batch()
             for i in range(4)]
    reassembled = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(whole["tokens"], reassembled)


def test_pipeline_resume_continues_stream():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=4, seed=0)
    p1 = TokenPipeline(cfg)
    b0, b1 = p1.next_batch(), p1.next_batch()
    state = p1.state_dict()
    b2_direct = p1.next_batch()
    p2 = TokenPipeline.restore(cfg, state)
    b2_resumed = p2.next_batch()
    np.testing.assert_array_equal(b2_direct["tokens"], b2_resumed["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=2)
    b = TokenPipeline(cfg).next_batch()
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -100).all()


# ------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip():
    params = init_params(jax.random.PRNGKey(0), TINY)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, "m", params, meta={"global_step": 7})
        like = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), TINY))
        restored = load_checkpoint(d, "m", like)
        assert load_meta(d, "m")["global_step"] == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises():
    params = init_params(jax.random.PRNGKey(0), TINY)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, "m", params)
        wrong = jax.eval_shape(lambda: init_params(
            jax.random.PRNGKey(0), TINY.with_overrides(d_model=128,
                                                       head_dim=64)))
        with pytest.raises(ValueError):
            load_checkpoint(d, "m", wrong)


# -------------------------------------------------------------- optimizer

def test_warmup_cosine_schedule_shape():
    sched = warmup_cosine_schedule(1.0, 10, 100)
    assert float(sched(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)
    assert float(sched(jnp.asarray(55))) < 1.0


def test_adamw_reduces_loss_on_quadratic():
    spec = OptimizerSpec(kind="adamw", peak_lr=0.1, warmup_steps=0,
                         total_steps=200, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(spec, params)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        params, state, _ = apply_updates(spec, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_grad_clipping_bounds_update():
    spec = OptimizerSpec(kind="sgd", peak_lr=1.0, warmup_steps=0,
                         total_steps=10, clip_norm=1.0, momentum=0.0)
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(spec, params)
    grads = {"w": jnp.full(4, 100.0)}
    new_params, _, m = apply_updates(spec, params, grads, state)
    assert float(global_norm(jax.tree.map(
        lambda a, b: a - b, params, new_params))) <= \
        float(m["lr"]) * 1.0 + 1e-5


# ------------------------------------------------------------ microbatch

def test_microbatch_grad_accumulation_matches_full_batch():
    spec = OptimizerSpec(peak_lr=1e-2, warmup_steps=0, total_steps=10,
                         weight_decay=0.0)
    state = init_train_state(jax.random.PRNGKey(0), TINY, spec)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 128)
    batch = {"tokens": toks, "labels": toks}
    s_full, m_full = make_train_step(TINY, spec, microbatches=1,
                                     remat=False)(state, batch)
    s_micro, m_micro = make_train_step(TINY, spec, microbatches=2,
                                       remat=False)(state, batch)
    assert abs(float(m_full["loss"]) - float(m_micro["loss"])) < 1e-5
    # grads match up to f32 accumulation order; Adam's rsqrt amplifies the
    # few-ulp difference, hence the looser parameter tolerance
    for a, b in zip(jax.tree.leaves(s_full["params"]),
                    jax.tree.leaves(s_micro["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=1e-4)


# ---------------------------------------------------------------- serving

def test_generate_shapes_and_determinism():
    params = init_params(jax.random.PRNGKey(0), TINY)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128)
    out1 = generate(params, TINY, prompts, max_new_tokens=4)
    out2 = generate(params, TINY, prompts, max_new_tokens=4)
    assert out1.shape == (2, 12)
    np.testing.assert_array_equal(out1, out2)       # greedy = deterministic
    assert (out1 >= 0).all() and (out1 < 128).all()


# ----------------------------------------------------------- elastic (1dev)

def test_elastic_save_kill_resume_single_device():
    """The protocol cycle on one device (multi-device covered by the
    subprocess integration test and examples)."""
    with tempfile.TemporaryDirectory() as d:
        ecfg = ElasticConfig(
            model=TINY,
            optimizer=OptimizerSpec(peak_lr=1e-3, warmup_steps=2,
                                    total_steps=50),
            data=DataConfig(vocab_size=128, seq_len=32, global_batch=4),
            ckpt_dir=d)
        tr = ElasticTrainer(ecfg, "app-x")
        tr.start(jax.devices()[:1])
        m1 = tr.train_steps(3)
        ckpt = tr.save_state()
        assert ckpt.step == 3
        tr.kill()
        assert tr.state is None
        tr.resume(jax.devices()[:1], ckpt)
        m2 = tr.train_steps(2)
        assert m2["step"] == 5
        # the data stream continued where it left off
        assert tr.pipeline.step == 5
