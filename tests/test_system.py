"""End-to-end behaviour tests for the paper's system: Dorm managing REAL
JAX training applications (the live integration of §III), plus the
multi-device elastic path via a subprocess with forced host devices."""
import json
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import numpy as np
import pytest

from repro.core import (ApplicationSpec, ClusterSpec, DormMaster,
                        OptimizerConfig, ResourceVector)
from repro.data import DataConfig
from repro.models.config import ModelConfig
from repro.training.elastic import (ElasticConfig, ElasticJaxProtocol,
                                    ElasticTrainer)
from repro.training.optimizer import OptimizerSpec

TINY = ModelConfig("tiny", "dense", 2, 64, 2, 2, 128, 128, head_dim=32,
                   dtype="float32", attn_impl="ref")


def test_dorm_drives_real_training_jobs():
    """DormMaster + ElasticJaxProtocol: submit two real training apps; the
    second submission forces a resize of the first via the checkpoint
    protocol; training continues without losing steps."""
    cluster = ClusterSpec.homogeneous(1, ResourceVector.of(4, 0, 16))
    proto = ElasticJaxProtocol(jax.devices(), devices_per_container=1,
                               oversubscribe=True)
    master = DormMaster(cluster, "greedy", OptimizerConfig(0.5, 1.0),
                        protocol=proto)

    with tempfile.TemporaryDirectory() as d:
        def make_trainer(app_id):
            return ElasticTrainer(ElasticConfig(
                model=TINY,
                optimizer=OptimizerSpec(peak_lr=1e-3, warmup_steps=2,
                                        total_steps=50),
                data=DataConfig(vocab_size=128, seq_len=32, global_batch=4),
                ckpt_dir=d), app_id)

        proto.register("j1", make_trainer("j1"))
        proto.register("j2", make_trainer("j2"))

        a1 = ApplicationSpec("j1", "repro", ResourceVector.of(1, 0, 4),
                             1, 4, 1)
        master.submit(a1)
        t1 = proto.trainers["j1"]
        assert t1.state is not None
        m = t1.train_steps(3)
        assert m["step"] == 3

        a2 = ApplicationSpec("j2", "repro", ResourceVector.of(1, 0, 4),
                             1, 4, 1)
        res = master.submit(a2)
        # both running; j1 may have been resized (killed+resumed)
        assert proto.trainers["j2"].state is not None
        m2 = t1.train_steps(2)
        assert m2["step"] == 5          # steps survived the adjustment
        master.complete("j1")
        master.complete("j2")


SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, json
    from repro.models.config import ModelConfig
    from repro.training.elastic import ElasticConfig, ElasticTrainer
    from repro.training.optimizer import OptimizerSpec
    from repro.data import DataConfig
    cfg = ElasticConfig(
        model=ModelConfig("t","dense",2,64,2,2,128,128,head_dim=32,
                          dtype="float32",attn_impl="ref"),
        optimizer=OptimizerSpec(peak_lr=1e-3, warmup_steps=2, total_steps=60),
        data=DataConfig(vocab_size=128, seq_len=32, global_batch=8))
    tr = ElasticTrainer(cfg, "sub")
    tr.start(jax.devices()[:2])
    a = tr.train_steps(4)
    tr.resize(jax.devices()[:8])
    b = tr.train_steps(4)
    tr.resize(jax.devices()[:1])
    c = tr.train_steps(2)
    print(json.dumps({"steps": c["step"], "losses":
                      [a["loss"], b["loss"], c["loss"]]}))
""")


@pytest.mark.slow
def test_elastic_multidevice_resize_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["steps"] == 10
    assert rec["losses"][-1] < rec["losses"][0] + 0.3
